"""Unified telemetry subsystem (repro/obs/, DESIGN.md S18): instruments,
ring-buffer overflow accounting, background drain, tracer + Chrome-trace
export, sinks, and the instrumented subsystem integration points."""

import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import MetricsRegistry, Tracer, get_sink, parse_spec


@pytest.fixture(autouse=True)
def _fresh_global():
    obs._reset_for_tests()
    yield
    obs._reset_for_tests()


# -- metrics -----------------------------------------------------------------


def test_counter_accumulates_and_labels_key_separately():
    reg = MetricsRegistry()
    reg.counter("msgs", schedule="mrd").add(3)
    reg.counter("msgs", schedule="mrd").add(4)
    reg.counter("msgs", schedule="ring").add(10)
    snap = reg.snapshot()
    assert snap["counters"]["msgs[schedule=mrd]"] == 7.0
    assert snap["counters"]["msgs[schedule=ring]"] == 10.0


def test_gauge_last_value_wins():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(3)
    g.set(9)
    assert reg.snapshot()["gauges"]["depth"] == 9.0


def test_gauge_accepts_device_array_materialized_at_drain():
    reg = MetricsRegistry()
    reg.gauge("loss").set(jnp.float32(2.5))  # stored by reference
    assert reg.snapshot()["gauges"]["loss"] == 2.5


def test_histogram_stats_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in [1.0, 2.0, 3.0, 4.0, 100.0]:
        h.observe(v)
    st = reg.snapshot()["histograms"]["lat"]
    assert st["count"] == 5 and st["min"] == 1.0 and st["max"] == 100.0
    assert st["sum"] == 110.0 and st["mean"] == 22.0
    assert st["p50"] == 3.0


def test_ring_overflow_drops_and_counts_never_blocks():
    reg = MetricsRegistry(capacity=8)
    c = reg.counter("x")
    for _ in range(20):
        c.add(1)
    assert reg.dropped == 12
    assert reg.summary()["dropped"] == 12
    reg.flush()
    # the 8 ring slots drained; overflow was dropped, not queued
    assert reg.snapshot()["counters"]["x"] == 8.0


def test_drain_frees_ring_capacity():
    reg = MetricsRegistry(capacity=8)
    c = reg.counter("x")
    for _ in range(8):
        c.add(1)
    reg.flush()
    for _ in range(8):
        c.add(1)
    reg.flush()
    assert reg.dropped == 0
    assert reg.snapshot()["counters"]["x"] == 16.0


def test_background_writer_drains_without_explicit_flush():
    reg = MetricsRegistry(capacity=64, interval=0.01)
    reg.start()
    try:
        reg.counter("bg").add(5)
        done = threading.Event()
        for _ in range(200):
            if reg.summary()["pending"] == 0 and reg.summary()["drained"] >= 1:
                done.set()
                break
            threading.Event().wait(0.01)
        assert done.is_set(), "writer thread never drained the ring"
    finally:
        reg.stop()
    assert reg.snapshot()["counters"]["bg"] == 5.0


def test_sink_receives_drained_batches():
    class Capture:
        name = "capture"

        def __init__(self):
            self.rows = []

        def write_metrics(self, batch):
            self.rows.extend(batch)

        def close(self, tracer=None):
            pass

    reg = MetricsRegistry()
    cap = Capture()
    reg._sink = cap
    reg.counter("a", k="v").add(2)
    reg.flush()
    assert len(cap.rows) == 1
    ts, kind, name, value, labels = cap.rows[0]
    assert kind == "counter" and name == "a" and value == 2.0
    assert dict(labels) == {"k": "v"}


# -- tracing -----------------------------------------------------------------


def test_span_records_duration_and_args():
    tr = Tracer()
    with tr.span("work", n=3) as sp:
        sp["m"] = 7  # attached mid-span, lands in the exported args
    evs = [e for e in tr.chrome_trace()["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == 1
    assert evs[0]["name"] == "work"
    assert evs[0]["args"] == {"n": 3, "m": 7}
    assert evs[0]["dur"] >= 0


def test_instant_and_span_counts():
    tr = Tracer()
    with tr.span("a"):
        tr.instant("mark", tick=1)
    s = tr.summary()
    assert s["spans"] == 1 and s["instants"] == 1 and s["dropped"] == 0
    assert tr.counts() == {"a": 1, "mark": 1}


def test_tracer_overflow_counts_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert tr.summary()["recorded"] == 4
    assert tr.summary()["dropped"] == 6


def test_chrome_trace_is_perfetto_shaped():
    tr = Tracer()
    with tr.span("outer"):
        tr.instant("inner")
    doc = tr.chrome_trace(process_name="test-proc")
    json.dumps(doc)  # serializable
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {m["name"] for m in metas}
    xs = [e for e in evs if e["ph"] == "X"]
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 1 and len(inst) == 1
    for e in xs + inst:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
    assert inst[0]["s"] == "t"
    # relative microsecond timestamps: instant falls inside the span
    assert xs[0]["ts"] <= inst[0]["ts"] <= xs[0]["ts"] + xs[0]["dur"]


def test_span_exception_still_records():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert tr.summary()["spans"] == 1


def test_writer_thread_gets_own_lane(tmp_path):
    tr = Tracer()
    with tr.span("main-side"):
        pass
    t = threading.Thread(target=lambda: tr.instant("thread-side"))
    t.start()
    t.join()
    evs = [e for e in tr.chrome_trace()["traceEvents"] if e["ph"] in "Xi"]
    tids = {e["name"]: e["tid"] for e in evs}
    assert tids["main-side"] != tids["thread-side"]


# -- sinks -------------------------------------------------------------------


def test_parse_spec():
    assert parse_spec("null") == ("null", None)
    assert parse_spec("jsonl:out.jsonl") == ("jsonl", "out.jsonl")
    assert parse_spec("chrome_trace:/tmp/t.json") == (
        "chrome_trace",
        "/tmp/t.json",
    )
    with pytest.raises(ValueError, match="unknown telemetry sink"):
        parse_spec("bogus")


def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    sink = get_sink(f"jsonl:{path}")
    sink.write_metrics([(123, "counter", "a", 2.0, (("k", "v"),))])
    sink.close()
    rows = [json.loads(l) for l in open(path)]
    assert rows[0] == {
        "ts_ns": 123,
        "kind": "counter",
        "name": "a",
        "value": 2.0,
        "labels": {"k": "v"},
    }


def test_csv_sink_round_trip(tmp_path):
    path = str(tmp_path / "t.csv")
    sink = get_sink(f"csv:{path}")
    sink.write_metrics([(123, "gauge", "g", 1.5, ())])
    sink.close()
    lines = open(path).read().strip().splitlines()
    assert lines[0] == "ts_ns,kind,name,value,labels"
    assert lines[1].startswith("123,gauge,g,1.5")


def test_chrome_trace_sink_writes_trace_at_close(tmp_path):
    path = str(tmp_path / "trace.json")
    sink = get_sink(f"chrome_trace:{path}")
    tr = Tracer()
    with tr.span("s"):
        pass
    sink.close(tr)
    doc = json.load(open(path))
    assert any(e.get("name") == "s" for e in doc["traceEvents"])


# -- global facade -----------------------------------------------------------


def test_disabled_by_default_records_nothing():
    assert not obs.enabled()
    with obs.span("never") as sp:
        assert sp is None
    obs.instant("never")
    assert obs.telemetry().tracer.summary()["recorded"] == 0


def test_configure_shutdown_round_trip(tmp_path):
    path = str(tmp_path / "out.json")
    obs.configure(f"chrome_trace:{path}", background=False)
    with obs.span("run", p=5):
        obs.instant("tick")
    obs.counter("n").add(1)
    summary = obs.shutdown()
    assert summary["spans"] == 1 and summary["instants"] == 1
    assert summary["sink"] == "chrome_trace"
    assert not obs.enabled()
    names = [e.get("name") for e in json.load(open(path))["traceEvents"]]
    assert "run" in names and "tick" in names


# -- instrumented subsystems -------------------------------------------------


def test_collective_plan_emits_paper_message_counts():
    from repro.collectives.plans import allreduce_plan
    from repro.core import topology

    p = 5
    obs.configure("null", background=False)
    plan = allreduce_plan(schedule="mrd", executor="sim", p=p)
    plan.run(jnp.ones((p, 8), jnp.float32))
    snap = obs.snapshot()
    assert snap["counters"]["coll.messages[schedule=mrd]"] == float(
        topology.paper_message_count(p)
    )
    _p0, _mu0, extra = topology.pivot(p)
    assert snap["counters"]["coll.extra_msgs[schedule=mrd]"] == float(2 * extra)
    stage_events = obs.telemetry().tracer.counts("coll.stage")
    assert stage_events["coll.stage"] == topology.paper_step_count(p)


def test_collective_run_buffers_scales_messages_by_bucket_count():
    from repro.collectives.plans import allreduce_plan
    from repro.core import topology

    p, n_bufs = 3, 4
    obs.configure("null", background=False)
    plan = allreduce_plan(schedule="mrd", executor="sim", p=p)
    plan.run_buffers([jnp.ones((p, 8), jnp.float32)] * n_bufs)
    snap = obs.snapshot()
    assert snap["counters"]["coll.messages[schedule=mrd]"] == float(
        n_bufs * topology.paper_message_count(p)
    )


def test_async_run_emits_certify_instant():
    from repro.asynchrony.engine import AsyncConfig, run
    from repro.asynchrony.solvers import make_solver

    obs.configure("null", background=False)
    fp = make_solver("poisson1d", n=64, shift=0.5, seed=0)
    res = run(fp, AsyncConfig(p=4, detection="exact", eps=1e-5, max_ticks=50000))
    assert res.detected
    counts = obs.telemetry().tracer.counts()
    assert counts["async.run"] == 1
    assert counts["protocol.certify"] == 1
    snap = obs.snapshot()
    assert snap["counters"]["async.messages_coll[protocol=exact]"] == float(
        res.messages_coll
    )


def test_serve_engine_summary_has_telemetry_subdict():
    from repro.serving import Request, ServeConfig, ServeEngine, make_workload

    obs.configure("null", background=False)
    wl = make_workload("fixedpoint_solve", solver="d_iteration", n=16, slots=2)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval"))
    rng = np.random.default_rng(0)
    v = rng.random(16).astype(np.float32)
    eng.run([Request(id=0, arrival=0, payload=v / v.sum(), max_new=400)])
    s = eng.summary()
    assert s["completed"] == 1
    tele = s["telemetry"]
    assert tele["enabled"] is True
    assert tele["spans"] > 0  # admit/tick spans recorded
    assert tele["events_dropped"] == 0
    counts = obs.telemetry().tracer.counts("serve.")
    assert counts["serve.admit"] >= 1
    assert counts["serve.tick"] >= 1
    assert counts["serve.retire"] == 1


def test_serve_engine_summary_telemetry_disabled_is_benign():
    from repro.serving import Request, ServeConfig, ServeEngine, make_workload

    wl = make_workload("fixedpoint_solve", solver="d_iteration", n=16, slots=2)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval"))
    rng = np.random.default_rng(0)
    v = rng.random(16).astype(np.float32)
    eng.run([Request(id=0, arrival=0, payload=v / v.sum(), max_new=400)])
    s = eng.summary()
    assert s["telemetry"]["enabled"] is False
    assert s["telemetry"]["spans"] == 0


def test_load_snapshot_single_source_for_policy_and_gauges():
    from repro.serving import Request, ServeConfig, ServeEngine, make_workload

    obs.configure("null", background=False)
    wl = make_workload("fixedpoint_solve", solver="d_iteration", n=16, slots=2)
    eng = ServeEngine(wl, ServeConfig(termination="residual_interval"))
    rng = np.random.default_rng(0)
    for i in range(4):  # more requests than slots: a queue forms
        v = rng.random(16).astype(np.float32)
        eng.submit(Request(id=i, arrival=0, payload=v / v.sum(), max_new=400))
    snap = eng.load_snapshot()
    assert snap.queue_depth == 4
    gauges = obs.snapshot()["gauges"]
    assert gauges["serve.queue_depth"] == float(snap.queue_depth)
    assert gauges["serve.free_slots"] == float(snap.free_slots)
    assert gauges["serve.dp"] == float(snap.dp)


def test_checkpointer_save_spans(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer

    obs.configure("null", background=False)
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.arange(8, dtype=jnp.float32), "step": jnp.int32(1)}
    ck.save(1, state, block=True)
    counts = obs.telemetry().tracer.counts("ckpt.")
    assert counts["ckpt.save.stage"] == 1
    assert counts["ckpt.d2h_wait"] == 1
    assert counts["ckpt.write"] == 1
    # the writer-thread spans carry a different tid than the caller's
    evs = obs.telemetry().tracer.events()
    tid = {name: t for _, name, _, _, t, _ in evs}
    assert tid["ckpt.write"] != tid["ckpt.save.stage"]
