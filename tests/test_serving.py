"""Continuous-batching serving subsystem (repro.serving, DESIGN.md S13).

Core claims under test:

1. **Bit-equivalence** — each request's greedy tokens under continuous
   batching (slot recycling, mixed admission, other slots mid-decode) are
   identical to decoding that request alone in a static batch, for a dense
   and a hybrid (SSM+attention) arch, with the termination agreement at
   dp ∈ {1, 2}.
2. **Termination agreement** — at non-power-of-two dp, a slot retires only
   when a full MRD agreement cycle certifies the *reduced* (max over
   replicas) view; one replica's locally-converged view never retires a
   slot early, and a request recycled into a slot mid-cycle can never be
   killed by its predecessor's latched done-bit.
3. **Certification soundness** — fixed-point requests retired by
   ``residual_interval`` / ``residual_inexact`` satisfy their residual
   bound at retirement (true ||f(x)-x||_inf < eps under the request's own
   payload), including at non-power-of-two dp; exhausted budgets retire
   as ``converged=False`` instead of certifying.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import registry
from repro.core import topology
from repro.distributed import step as step_lib
from repro.models import transformer
from repro.serving import (
    SCHEDULERS,
    TERMINATION,
    WORKLOADS,
    Request,
    ServeConfig,
    ServeEngine,
    TerminationConfig,
    get_scheduler,
    get_termination,
    make_workload,
)
from repro.serving.termination import make_signals


def _mesh():
    return compat.make_mesh(
        (1,), ("data",), devices=jax.devices()[:1],
        axis_types=compat.default_axis_types(1),
    )


# ---------------------------------------------------------------------------
# Registry floors
# ---------------------------------------------------------------------------


def test_registry_floors():
    assert {"fcfs", "priority", "sla_edf"} <= set(SCHEDULERS)
    assert {"eos_maxlen", "residual_inexact", "residual_interval"} <= set(
        TERMINATION
    )
    assert {"llm_decode", "fixedpoint_solve"} <= set(WORKLOADS)


def test_scheduler_ordering():
    class R:
        def __init__(self, id, arrival, priority=0, sla=None):
            self.id, self.arrival = id, arrival
            self.priority, self.sla = priority, sla

    q = [R(0, 5), R(1, 2, priority=1), R(2, 3, sla=4), R(3, 1, sla=100)]
    fcfs = get_scheduler("fcfs").select(q, [0, 1, 2, 3], now=9)
    assert [r.id for r, _ in fcfs] == [3, 1, 2, 0]
    prio = get_scheduler("priority").select(q, [0, 1], now=9)
    assert [r.id for r, _ in prio] == [1, 3]  # high priority first, then FCFS
    edf = get_scheduler("sla_edf").select(q, [0, 1, 2], now=9)
    # deadlines: r2 at 7, r3 at 101, others inf (FCFS among themselves)
    assert [r.id for r, _ in edf] == [2, 3, 1]
    # slots assigned lowest-first, at most len(free)
    assert [s for _, s in edf] == [0, 1, 2]
    assert get_scheduler("fcfs").select(q, [], now=9) == []


# ---------------------------------------------------------------------------
# 1. Continuous batching == solo static decode, bit-exact tokens
# ---------------------------------------------------------------------------


def _solo_decode(cfg, mesh, params, prompt, max_new):
    """The request decoded alone in a static batch (the PR-4 serve path)."""
    serve_step, _ = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)
    jstep, jprefill = jax.jit(serve_step), jax.jit(prefill_step)
    S = int(prompt.shape[0])
    with mesh:
        cache = transformer.init_cache(cfg, 1, S + max_new + 1)
        logits, cache = jprefill(params, jnp.asarray(prompt[None]), cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        for k in range(max_new - 1):
            logits, cache = jstep(
                params, jnp.asarray(toks[-1:], jnp.int32), cache,
                jnp.int32(S + k),
            )
            toks.append(int(jnp.argmax(logits, -1)[0]))
    return np.asarray(toks, np.int32)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b"])
def test_continuous_matches_solo_decode(arch):
    cfg = registry.get_smoke_config(arch)
    mesh = _mesh()
    rng = np.random.default_rng(3)
    # 5 requests over 2 slots: recycling is forced, admissions land while
    # other slots are mid-decode, and lengths are mixed
    prompts = [rng.integers(0, cfg.vocab, size=L) for L in (3, 5, 8, 5, 3)]
    max_new = [6, 4, 7, 5, 6]
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=2, max_len=24,
        max_prompt_len=8, seed=0,
    )
    solo = [
        _solo_decode(cfg, mesh, workload.params, p, m)
        for p, m in zip(prompts, max_new)
    ]
    for dp in (1, 2):
        workload.reset()
        eng = ServeEngine(workload, ServeConfig(
            scheduler="fcfs", termination="eos_maxlen", dp=dp,
        ))
        reqs = [
            Request(id=i, arrival=i, prompt=p, max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_new))
        ]
        res = eng.run(reqs)
        assert len(res) == len(reqs)
        for i, want in enumerate(solo):
            np.testing.assert_array_equal(
                res[i].output, want,
                err_msg=f"{arch} dp={dp} request {i}: continuous != solo",
            )


def test_eos_terminates_early():
    """A request whose EOS id appears in its solo stream retires right
    there, with the stream trimmed through the EOS token."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=2, max_len=24,
        max_prompt_len=8, seed=0,
    )
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=5)
    solo = _solo_decode(cfg, mesh, workload.params, prompt, 8)
    eos = int(solo[3])  # pretend the 4th generated token is EOS
    want = solo[: int(np.nonzero(solo == eos)[0][0]) + 1]
    eng = ServeEngine(workload, ServeConfig(termination="eos_maxlen"))
    res = eng.run([Request(id=0, prompt=prompt, max_new=8, eos=eos)])
    np.testing.assert_array_equal(res[0].output, want)
    assert res[0].n_tokens == want.shape[0] < 8


# ---------------------------------------------------------------------------
# 2. Agreement at non-power-of-two dp (protocol-level, synthetic signals)
# ---------------------------------------------------------------------------


def _sig(dp, slots, *, tick, active, admit_tick, residual, eps=1e-3):
    return make_signals(
        tokens=jnp.zeros((slots,), jnp.int32),
        new_tokens=jnp.full((slots,), 5, jnp.int32),
        eos=jnp.full((slots,), -1, jnp.int32),
        max_new=jnp.full((slots,), 1000, jnp.int32),
        eps=jnp.full((slots,), eps, jnp.float32),
        active=jnp.asarray(active),
        admit_tick=jnp.asarray(admit_tick, jnp.int32),
        tick=jnp.int32(tick),
        residual=jnp.asarray(residual, jnp.float32),
    )


@pytest.mark.parametrize("dp", [3, 5, 6])
def test_residual_interval_waits_for_agreed_max(dp):
    """One replica's converged local view must not retire the slot: the
    agreed value is the max over replicas, reduced by a full MRD cycle."""
    term = get_termination("residual_interval")
    tcfg = TerminationConfig(dp=dp, eps=1e-3, window=1)
    slots = 2
    st = term.init(tcfg, slots)
    cyc = term.cycle_length(tcfg)
    assert cyc == len(topology.allreduce_schedule(dp))
    active = np.ones((slots,), bool)
    admit = np.zeros((slots,), np.int32)

    # replica 0 sees 1e-6 (locally converged), replica dp-1 sees 1.0
    mixed = np.full((dp, slots), 1e-6, np.float32)
    mixed[-1, :] = 1.0
    tick = 0
    for _ in range(3 * cyc):  # several full cycles of disagreement
        st, retire = term.tick(
            st, _sig(dp, slots, tick=tick, active=active, admit_tick=admit,
                     residual=mixed), tcfg,
        )
        assert not bool(np.asarray(retire).any()), "retired on a local view"
        tick += 1

    # all replicas below eps: certification lands exactly on the next
    # completed cycle (same tick for every replica, by construction)
    low = np.full((dp, slots), 1e-6, np.float32)
    seen = []
    for k in range(2 * cyc + 1):
        st, retire = term.tick(
            st, _sig(dp, slots, tick=tick, active=active, admit_tick=admit,
                     residual=low), tcfg,
        )
        r = np.asarray(retire)
        assert r.all() or not r.any(), "slots must retire together here"
        if r.any():
            seen.append(tick)
            break
        tick += 1
    assert seen, f"no certification within two cycles at dp={dp}"
    certified = np.asarray(st["certified"])
    assert (certified < tcfg.eps).all()


@pytest.mark.parametrize("dp", [1, 4])
def test_recycled_slot_survives_stale_cycle(dp):
    """eos_maxlen: a done-bit latched for the *previous* occupant of a slot
    must not retire the request admitted into that slot mid-cycle."""
    term = get_termination("eos_maxlen")
    tcfg = TerminationConfig(dp=dp)
    slots = 1
    st = term.init(tcfg, slots)
    cyc = term.cycle_length(tcfg)

    def sig(tick, new_tokens, max_new, admit_tick):
        return make_signals(
            tokens=jnp.zeros((slots,), jnp.int32),
            new_tokens=jnp.asarray([new_tokens], jnp.int32),
            eos=jnp.full((slots,), -1, jnp.int32),
            max_new=jnp.asarray([max_new], jnp.int32),
            eps=jnp.ones((slots,), jnp.float32),
            active=jnp.ones((slots,), bool),
            admit_tick=jnp.asarray([admit_tick], jnp.int32),
            tick=jnp.int32(tick),
            residual=jnp.zeros((dp, slots), jnp.float32),
        )

    # old request is done (budget hit) -> latched at cycle start (tick 0)
    retired_at = None
    for t in range(cyc):
        # at t >= 1, the slot has been recycled: a fresh request (admitted
        # at t=1, 1 token so far, budget 100) occupies it
        if t == 0:
            st, retire = term.tick(st, sig(t, 10, 10, admit_tick=0), tcfg)
        else:
            st, retire = term.tick(st, sig(t, 1 + t, 100, admit_tick=1), tcfg)
        if bool(np.asarray(retire)[0]):
            retired_at = t
    if dp == 1:
        # no lag at dp=1: the old request retires on its own tick
        assert retired_at == 0
    else:
        # the cycle completes with the old done-bit agreed, but the guard
        # (admit_tick > t_latch) protects the recycled slot
        assert retired_at is None


# ---------------------------------------------------------------------------
# 3. Fixed-point serving: certification soundness end to end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dp", [1, 3, 5])
@pytest.mark.parametrize("protocol", ["residual_interval", "residual_inexact"])
def test_fixedpoint_certification_sound(protocol, dp):
    eps = 1e-6
    n = 60
    workload = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=n, dp=dp, slots=3,
        damping=0.7, seed=1,
    )
    eng = ServeEngine(workload, ServeConfig(
        scheduler="fcfs", termination=protocol, dp=dp, eps=eps,
    ))
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(5):
        v = rng.random(n).astype(np.float32)
        reqs.append(Request(id=i, arrival=2 * i, payload=v / v.sum(),
                            max_new=800))
    res = eng.run(reqs)
    assert len(res) == len(reqs)
    for i, r in sorted(res.items()):
        assert r.converged, f"request {i} not certified"
        assert r.certified < eps
        # the residual bound at retirement: true ||f(x)-x||_inf under the
        # request's own payload is below eps (update magnitudes contract
        # monotonically, so the agreed window max dominates the truth)
        v = jnp.asarray(reqs[i].payload)
        x = jnp.asarray(r.output)
        true_res = float(jnp.max(jnp.abs(workload.pool.param_map(x, v) - x)))
        assert true_res < eps, (i, true_res)


def test_fixedpoint_budget_forces_unconverged_retirement():
    workload = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=30, dp=2, slots=2,
        damping=0.9,
    )
    eng = ServeEngine(workload, ServeConfig(
        termination="residual_interval", dp=2, eps=1e-12,  # unreachably tight
    ))
    res = eng.run([Request(id=0, max_new=20)])
    assert not res[0].converged
    assert res[0].certified >= 1e-12  # never certified (RES_INIT or large)
    # the budget is exact: admission performs no iteration, so a forced
    # fixed-point request retires after exactly max_new pool iterations
    assert res[0].n_tokens == 20
    assert res[0].retire_tick - res[0].admit_tick == 19


def test_forced_retirement_does_not_inherit_recycled_certification():
    """A budget-forced request in a recycled slot must not report the
    certified residual of the slot's previous occupant."""
    workload = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=30, dp=1, slots=1,
        damping=0.5,
    )
    eng = ServeEngine(workload, ServeConfig(
        termination="residual_inexact", dp=1, eps=1e-4,
    ))
    res = eng.run([
        Request(id=0, max_new=500),                 # certifies at < 1e-4
        Request(id=1, max_new=5, eps=1e-12),        # forced out, same slot
    ])
    assert res[0].converged and res[0].certified < 1e-4
    assert not res[1].converged
    assert res[1].certified >= 1e-4, "inherited the predecessor's residual"


def test_poisson1d_affine_payload_serves_distinct_rhs():
    """The affine-payload pool solves *different* systems per slot: each
    retired solution satisfies its own rhs, not the shared base one."""
    n, dp, eps = 32, 2, 1e-5  # above the float32 update-noise floor at |x|~1
    workload = make_workload(
        "fixedpoint_solve", solver="poisson1d", n=n, dp=dp, slots=2,
        shift=2.0,  # strongly diagonally dominant -> fast contraction
    )
    rng = np.random.default_rng(11)
    payloads = [rng.uniform(-5, 5, size=n).astype(np.float32) for _ in range(3)]
    eng = ServeEngine(workload, ServeConfig(
        termination="residual_interval", dp=dp, eps=eps,
    ))
    res = eng.run([
        Request(id=i, arrival=i, payload=p, max_new=3000)
        for i, p in enumerate(payloads)
    ])
    sols = []
    for i, r in sorted(res.items()):
        assert r.converged
        v = jnp.asarray(payloads[i])
        x = jnp.asarray(r.output)
        assert float(jnp.max(jnp.abs(workload.pool.param_map(x, v) - x))) < eps
        sols.append(r.output)
    assert not np.allclose(sols[0], sols[1])  # genuinely different systems


# ---------------------------------------------------------------------------
# Engine mechanics
# ---------------------------------------------------------------------------


def test_engine_rejects_residual_termination_for_llm():
    cfg = registry.get_smoke_config("llama3.2-1b")
    workload = make_workload(
        "llm_decode", cfg=cfg, mesh=_mesh(), slots=2, max_len=16,
        max_prompt_len=4,
    )
    with pytest.raises(ValueError, match="residual"):
        ServeEngine(workload, ServeConfig(termination="residual_interval"))


def test_summary_metrics_present():
    workload = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=20, dp=1, slots=2,
        damping=0.5,
    )
    eng = ServeEngine(workload, ServeConfig(
        termination="residual_inexact", eps=1e-5,
    ))
    eng.run([Request(id=0, max_new=200), Request(id=1, arrival=3, max_new=200)])
    s = eng.summary()
    assert s["completed"] == 2 and s["converged"] == 2
    for k in ("throughput_tok_s", "ttft_p50_ms", "ttft_p95_ms",
              "tpot_p50_ms", "tpot_p95_ms", "occupancy", "wall_s"):
        assert np.isfinite(s[k]), k
    assert 0 < s["occupancy"] <= 1
