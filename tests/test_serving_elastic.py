"""Elastic serving: resize the engine's replica extent under live traffic
(DESIGN.md S15).

In-process units for the machinery the serving chaos suite
(``test_chaos_serving.py``) drives end to end:

- the stacked MRD sum-broadcast (``mrd_broadcast_stacked``) is bit-exact
  at power-of-two and non-power-of-two extents, for float/int/bool and
  zero-size leaves — the grow path's state transfer;
- the termination protocols survive ``migrate`` mid-agreement-window: a
  locally-converged surviving replica never retires a slot early after a
  5→3 shrink or a 3→5 grow, the staged reduction restarts at the new
  extent, and certified bounds still hold at retirement;
- :meth:`ServeEngine.resize` under live fixed-point and LLM traffic
  loses no request, re-prefills no slot, and (LLM) retires tokens
  bit-identical to an uninterrupted run;
- bounded capacity requeue (``ServeConfig.max_retries``) surfaces retry
  counts, and a crashed fused dispatch rolls its block reservations back
  to the allocator instead of leaking them;
- the :class:`ElasticServeController` keep-map algebra (ReplicaSet,
  clamp_min_extent) and the min-extent spare/resurrect path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import registry
from repro.distributed.serve import mrd_broadcast_stacked
from repro.runtime import (
    ElasticServeController,
    ReplicaSet,
    ResizeDecision,
    clamp_min_extent,
)
from repro.serving import (
    Request,
    ServeConfig,
    ServeEngine,
    TerminationConfig,
    get_termination,
    make_workload,
)
from repro.serving.termination import make_signals


def _mesh():
    return compat.make_mesh(
        (1,), ("data",), devices=jax.devices()[:1],
        axis_types=compat.default_axis_types(1),
    )


def _sig(dp, slots, *, tick, active, admit_tick, residual, eps=1e-3):
    return make_signals(
        tokens=jnp.zeros((slots,), jnp.int32),
        new_tokens=jnp.full((slots,), 5, jnp.int32),
        eos=jnp.full((slots,), -1, jnp.int32),
        max_new=jnp.full((slots,), 1000, jnp.int32),
        eps=jnp.full((slots,), eps, jnp.float32),
        active=jnp.asarray(active),
        admit_tick=jnp.asarray(admit_tick, jnp.int32),
        tick=jnp.int32(tick),
        residual=jnp.asarray(residual, jnp.float32),
    )


# ---------------------------------------------------------------------------
# 1. Stacked MRD broadcast: bit-exact at any extent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [2, 3, 5])
def test_mrd_broadcast_stacked_bit_exact(p):
    rng = np.random.default_rng(17)
    tree = {
        "f32": rng.standard_normal((7, 5)).astype(np.float32) * 1e3,
        "i32": rng.integers(-(2**30), 2**30, size=(11,)).astype(np.int32),
        "flags": rng.random((6,)) < 0.5,
        "empty": np.zeros((0, 4), np.float32),
    }
    out = mrd_broadcast_stacked(tree, p, src=0)
    for k in tree:
        got, want = np.asarray(out[k]), tree[k]
        assert got.dtype == want.dtype and got.shape == want.shape, k
        if want.dtype == np.float32:
            np.testing.assert_array_equal(
                got.view(np.uint32), want.view(np.uint32),
                err_msg=f"p={p} leaf {k} not bit-identical",
            )
        else:
            np.testing.assert_array_equal(got, want, err_msg=f"p={p} {k}")


# ---------------------------------------------------------------------------
# 2. Termination migrate mid-window (satellite: 5→3 and 3→5)
# ---------------------------------------------------------------------------


def test_residual_interval_migrate_shrink_mid_window():
    """5→3 mid-window: the surviving locally-converged replica (rank 0)
    must not retire the slot after the shrink — the agreed value is still
    the max over the *new* replica group, and the migrated per-replica
    interval windows keep the survivors' high-water marks."""
    term = get_termination("residual_interval")
    t5 = TerminationConfig(dp=5, eps=1e-3, window=0)
    t3 = TerminationConfig(dp=3, eps=1e-3, window=0)
    slots = 2
    st = term.init(t5, slots)
    active = np.ones((slots,), bool)
    admit = np.zeros((slots,), np.int32)

    # replica 0 locally converged, replica 1 far from it
    mixed5 = np.full((5, slots), 1e-6, np.float32)
    mixed5[1, :] = 1.0
    tick = 0
    for _ in range(term.cycle_length(t5) // 2 + 1):  # stop mid-cycle
        st, retire = term.tick(
            st, _sig(5, slots, tick=tick, active=active, admit_tick=admit,
                     residual=mixed5), t5)
        assert not bool(np.asarray(retire).any())
        tick += 1

    # kill replicas 3 and 4; survivors 0,1,2 keep their rows (the derived
    # window length differs across extents, so this also exercises the
    # conservative max-fill reshape)
    st = term.migrate(st, (0, 1, 2), t3, slots)

    mixed3 = np.full((3, slots), 1e-6, np.float32)
    mixed3[1, :] = 1.0
    cyc3 = term.cycle_length(t3)
    for _ in range(3 * cyc3 + 3):
        st, retire = term.tick(
            st, _sig(3, slots, tick=tick, active=active, admit_tick=admit,
                     residual=mixed3), t3)
        assert not bool(np.asarray(retire).any()), (
            "retired while a surviving replica still reports 1.0"
        )
        tick += 1

    # everyone converges -> certification within window + two cycles
    low = np.full((3, slots), 1e-6, np.float32)
    window = t3.window or cyc3 + 1
    retired = np.zeros((slots,), bool)
    for _ in range(window + 3 * cyc3):
        st, retire = term.tick(
            st, _sig(3, slots, tick=tick, active=active, admit_tick=admit,
                     residual=low), t3)
        retired |= np.asarray(retire)
        active = active & ~np.asarray(retire)
        tick += 1
        if retired.all():
            break
    assert retired.all(), "did not certify after the shrink"
    cert = np.asarray(st["certified"])
    assert (cert < 1e-3).all(), cert


def test_residual_interval_migrate_grow_mid_window():
    """3→5 mid-window: joiners get fresh (conservative) rows, the staged
    reduction restarts at the new extent — so nothing can retire before a
    full agreement cycle at dp=5 completes, a joiner's high residual blocks
    retirement, and the certified bound still holds once everyone is low."""
    term = get_termination("residual_interval")
    t3 = TerminationConfig(dp=3, eps=1e-3, window=0)
    t5 = TerminationConfig(dp=5, eps=1e-3, window=0)
    slots = 2
    st = term.init(t3, slots)
    active = np.ones((slots,), bool)
    admit = np.zeros((slots,), np.int32)

    mixed3 = np.full((3, slots), 1e-6, np.float32)
    mixed3[2, :] = 1.0
    tick = 0
    for _ in range(term.cycle_length(t3) // 2 + 1):
        st, retire = term.tick(
            st, _sig(3, slots, tick=tick, active=active, admit_tick=admit,
                     residual=mixed3), t3)
        assert not bool(np.asarray(retire).any())
        tick += 1

    st = term.migrate(st, (0, 1, 2, None, None), t5, slots)
    cyc5 = term.cycle_length(t5)

    # all survivors low but the new joiner (rank 4) still high: the cycle
    # restart means no retirement within the first new cycle, and none
    # after either while the joiner's residual dominates the agreed max
    joiner_high = np.full((5, slots), 1e-6, np.float32)
    joiner_high[4, :] = 1.0
    for k in range(3 * cyc5 + 3):
        st, retire = term.tick(
            st, _sig(5, slots, tick=tick, active=active, admit_tick=admit,
                     residual=joiner_high), t5)
        assert not bool(np.asarray(retire).any()), f"retired at tick {k}"
        tick += 1

    low = np.full((5, slots), 1e-6, np.float32)
    window = t5.window or cyc5 + 1
    retired = np.zeros((slots,), bool)
    for _ in range(window + 3 * cyc5):
        st, retire = term.tick(
            st, _sig(5, slots, tick=tick, active=active, admit_tick=admit,
                     residual=low), t5)
        retired |= np.asarray(retire)
        active = active & ~np.asarray(retire)
        tick += 1
        if retired.all():
            break
    assert retired.all(), "did not certify after the grow"
    assert (np.asarray(st["certified"]) < 1e-3).all()


@pytest.mark.parametrize("protocol", ["eos_maxlen", "residual_inexact"])
def test_migrate_preserves_certified_latch(protocol):
    """Every protocol's migrate keeps the per-slot certified latch — a
    request that certified before the resize stays certified after it."""
    term = get_termination(protocol)
    t4 = TerminationConfig(dp=4, eps=1e-3)
    t3 = TerminationConfig(dp=3, eps=1e-3)
    st = term.init(t4, 3)
    st["certified"] = jnp.asarray([0.5, 1e-9, 0.5], jnp.float32)
    new = term.migrate(st, (0, 1, 3), t3, 3)
    np.testing.assert_array_equal(
        np.asarray(new["certified"]), np.asarray(st["certified"])
    )


# ---------------------------------------------------------------------------
# 3. Engine resize under live fixed-point traffic (4-visits: 5→3→5)
# ---------------------------------------------------------------------------


def test_fixedpoint_engine_resize_under_traffic():
    eps = 1e-6
    n = 60  # divisible by every visited extent
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=n, dp=5, slots=3,
        damping=0.7, seed=1,
    )
    eng = ServeEngine(wl, ServeConfig(
        scheduler="fcfs", termination="residual_interval", dp=5, eps=eps,
        steps_per_dispatch=4,
    ))
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):
        v = rng.random(n).astype(np.float32)
        reqs.append(Request(id=i, arrival=3 * i, payload=v / v.sum(),
                            max_new=800))
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()
    ev = eng.resize(3, (0, 2, 4), reason="killed 1,3")
    assert ev.kind == "shrink" and (ev.old_dp, ev.new_dp) == (5, 3)
    eng.step()
    eng.step()
    ev = eng.resize(5, (0, 1, 2, None, None), reason="two joiners")
    assert ev.kind == "grow" and (ev.old_dp, ev.new_dp) == (3, 5)
    res = eng.run([])
    assert len(res) == 6
    assert eng.summary()["resizes"] == 2
    for i, r in sorted(res.items()):
        assert r.converged, f"request {i} lost certification across resizes"
        assert r.certified < eps
        v = jnp.asarray(reqs[i].payload)
        x = jnp.asarray(r.output)
        true_res = float(jnp.max(jnp.abs(wl.pool.param_map(x, v) - x)))
        assert true_res < eps, (i, true_res)


def test_resize_rejects_bad_keep_and_noop():
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=12, dp=2, slots=2,
        damping=0.5,
    )
    eng = ServeEngine(wl, ServeConfig(termination="residual_inexact", dp=2))
    with pytest.raises(ValueError, match="keep"):
        eng.resize(3, (0, 1))  # keep map does not cover new_dp
    with pytest.raises(ValueError, match="outside"):
        eng.resize(2, (0, 5))
    assert eng.resize(2, (0, 1)) is None  # identity resize is a no-op
    assert eng.resizes == []


# ---------------------------------------------------------------------------
# 4. LLM: tokens survive grow+shrink bit-identically, zero re-prefill
# ---------------------------------------------------------------------------


def test_llm_tokens_survive_resize_no_reprefill():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=L) for L in (3, 5, 8, 4)]
    max_new = [6, 4, 7, 5]

    def reqs():
        return [
            Request(id=i, arrival=[0, 1, 4, 6][i], prompt=p, max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_new))
        ]

    wl = make_workload(
        "llm_decode", cfg=cfg, mesh=mesh, slots=2, max_len=24,
        max_prompt_len=8, seed=0,
    )
    # oracle: the same traffic, uninterrupted at dp=2
    want = ServeEngine(wl, ServeConfig(dp=2)).run(reqs())
    assert wl.prefills == len(prompts)

    wl.reset()
    assert wl.prefills == 0
    eng = ServeEngine(wl, ServeConfig(dp=2, steps_per_dispatch=2))
    for r in reqs():
        eng.submit(r)
    eng.step()
    assert eng.resize(3, (0, 1, None), reason="joiner").kind == "grow"
    eng.step()
    assert eng.resize(2, (0, 2), reason="killed 1").kind == "shrink"
    res = eng.run([])

    assert len(res) == len(prompts), "request lost across resize"
    # LLM pool state is slot-indexed and replica-independent: a resize
    # must never re-prefill a slot
    assert wl.prefills == len(prompts), "resize re-prefilled a slot"
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            res[i].output, want[i].output,
            err_msg=f"request {i}: tokens diverged across resize",
        )


# ---------------------------------------------------------------------------
# 5. Bounded capacity requeue (satellite: max_retries)
# ---------------------------------------------------------------------------


def test_capacity_retry_bounded_and_surfaced():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    wl = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=1, max_len=16,
        max_prompt_len=8, seed=0, block_size=8,
    )
    # defeat the budget clamp so the slot freezes at cache capacity with
    # budget unspent (the forced_at_capacity path)
    wl.clamp_max_new = lambda req: int(req.max_new)
    eng = ServeEngine(wl, ServeConfig(max_retries=2))
    res = eng.run([Request(id=0, prompt=np.arange(4) + 1, max_new=500)])
    s = eng.summary()
    # each attempt hits capacity; after max_retries requeues it retires
    assert s["forced_at_capacity"] == 3
    assert s["retried"] == 2
    assert res[0].retries == 2
    assert not res[0].converged
    assert wl.prefills == 3  # each retry is a fresh admission by design
    # every attempt's block reservation was returned
    assert wl.pool.allocator.used_blocks == 0
    wl.pool.allocator.check()


def test_max_retries_zero_keeps_fail_fast():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    wl = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=1, max_len=16,
        max_prompt_len=8, seed=0, block_size=8,
    )
    wl.clamp_max_new = lambda req: int(req.max_new)
    eng = ServeEngine(wl, ServeConfig())  # default: no retries
    res = eng.run([Request(id=0, prompt=np.arange(4) + 1, max_new=500)])
    assert eng.summary()["forced_at_capacity"] == 1
    assert eng.summary()["retried"] == 0
    assert res[0].retries == 0 and not res[0].converged


# ---------------------------------------------------------------------------
# 6. Exception-safe block release (satellite: crashed dispatch rollback)
# ---------------------------------------------------------------------------


def test_crashed_dispatch_rolls_back_blocks_and_requeues():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    wl = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=2, max_len=16,
        max_prompt_len=8, seed=0, block_size=8,
    )
    rng = np.random.default_rng(13)
    reqs = [
        Request(id=i, prompt=rng.integers(0, cfg.vocab, size=4), max_new=5)
        for i in range(2)
    ]
    free_before = wl.pool.allocator.free_blocks
    eng = ServeEngine(wl, ServeConfig())
    real = eng._jfused

    def boom(*a, **k):
        raise RuntimeError("injected device fault")

    eng._jfused = boom
    for r in reqs:
        eng.submit(r)
    with pytest.raises(RuntimeError, match="injected device fault"):
        eng.step()
    # the admitted slots' reservations were rolled back, nothing leaked
    assert wl.pool.allocator.used_blocks == 0
    assert wl.pool.allocator.free_blocks == free_before
    wl.pool.allocator.check()
    # both requests are back in the queue, no slot thinks it is active
    assert sorted(r.id for r in eng.queue) == [0, 1]
    assert all(s is None for s in eng.slot_req)
    assert not eng.active.any()

    # recovery: restore the dispatch and drain — clean re-admissions
    eng._jfused = real
    res = eng.run([])
    assert len(res) == 2 and all(r.converged for r in res.values())
    assert wl.pool.allocator.used_blocks == 0
    wl.pool.allocator.check()


# ---------------------------------------------------------------------------
# 7. Controller algebra: ReplicaSet, clamp_min_extent, spare/resurrect
# ---------------------------------------------------------------------------


def test_replica_set_keep_maps():
    rs = ReplicaSet([0, 1, 2, 3])
    ids, keep = rs.remove({2})
    assert ids == (0, 1, 3) and keep == (0, 1, 3)
    ids, keep = rs.add([4, 5])
    assert ids == (0, 1, 3, 4, 5)
    assert keep == (0, 1, 2, None, None)
    ids, keep = rs.add([4])  # already present: no-op join
    assert ids == (0, 1, 3, 4, 5) and keep == (0, 1, 2, 3, 4)
    with pytest.raises(RuntimeError, match="no live replicas"):
        rs.remove({0, 1, 3, 4, 5})
    with pytest.raises(ValueError, match="duplicate"):
        ReplicaSet([1, 1])


def test_clamp_min_extent():
    d = ResizeDecision("shrink", remove=frozenset({0, 1, 2}), reason="hb")
    # enough survivors: untouched
    assert clamp_min_extent(d, [0, 1, 2, 3], 1) is d
    # all victims spared -> suppressed no-op decision
    out = clamp_min_extent(d, [0, 1, 2], 3)
    assert out.action == "none" and "suppressed" in out.reason
    # partial sparing keeps the lowest ids
    out = clamp_min_extent(d, [0, 1, 2, 3], 3)
    assert out.action == "shrink" and out.remove == frozenset({2})
    assert "clamped" in out.reason
    # non-shrink decisions pass through
    g = ResizeDecision("grow", admit=(7,))
    assert clamp_min_extent(g, [0], 1) is g


def test_controller_min_extent_spares_and_serves_on():
    """Killing every replica must not kill the pool: clamp_min_extent pins
    it at one replica, the spared replica is pressed back into service,
    and all traffic still completes."""
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=60, dp=2, slots=2,
        damping=0.7, seed=1,
    )
    eng = ServeEngine(wl, ServeConfig(
        termination="residual_inexact", dp=2, eps=1e-5,
        steps_per_dispatch=4,
    ))
    ctl = ElasticServeController(eng, policy="shrink_on_failure",
                                 min_extent=1)
    ctl.kill(0)
    ctl.kill(1)
    res = ctl.run([
        Request(id=0, max_new=500),
        Request(id=1, arrival=2, max_new=500),
    ])
    assert len(res) == 2 and all(r.converged for r in res.values())
    assert eng.dp == 1
    assert [(e.old_dp, e.new_dp) for e in ctl.resizes] == [(2, 1)]
    # the spared replica was resurrected, not left flapping
    assert ctl.health[0] == "ok"


def test_controller_rejects_mismatched_replica_ids():
    wl = make_workload(
        "fixedpoint_solve", solver="d_iteration", n=12, dp=2, slots=2,
        damping=0.5,
    )
    eng = ServeEngine(wl, ServeConfig(termination="residual_inexact", dp=2))
    with pytest.raises(ValueError, match="replica ids"):
        ElasticServeController(eng, replica_ids=[0, 1, 2])
