"""Hypothesis property tests on the paper's core invariants (E3 hardened) +
launcher helper properties."""

import jax
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import async_engine as ae
from repro.core import mrd, solvers
from repro.core.topology import paper_message_count, pivot


@given(
    p=st.sampled_from([2, 3, 4, 6]),
    max_delay=st.integers(1, 5),
    activity=st.floats(0.3, 1.0),
    seed=st.integers(0, 50),
)
@settings(max_examples=8, deadline=None)
def test_exact_detector_never_lies(p, max_delay, activity, seed):
    """E3 (hardened): across random delay bounds, activity rates and seeds,
    a fired exact detector ALWAYS returns a certified solution."""
    fp = solvers.poisson_1d(48, omega=1.0, shift=0.8, seed=seed)
    cfg = ae.AsyncConfig(
        p=p, detection="exact", eps=1e-4, max_ticks=40000,
        max_delay=max_delay, activity=activity, seed=seed,
    )
    res = ae.run(fp, cfg)
    if res.detected:  # must both fire and certify under these settings
        assert res.true_res < cfg.eps, (p, max_delay, activity, seed, res.true_res)
    else:
        pytest.fail(f"exact detector did not fire within budget (p={p})")


@given(
    p=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_allreduce_idempotent_on_reduced_values(p, seed):
    """Allreducing an already-reduced (identical-rows) input is the identity —
    the fixed-point property of the butterfly."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    row = jnp.asarray(rng.standard_normal(5), jnp.float32)
    x = jnp.broadcast_to(row, (p, 5))
    out = mrd.sim_allreduce(x, op="max")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


@given(p=st.integers(1, 300))
@settings(max_examples=60, deadline=None)
def test_message_count_monotone_in_pivot_class(p):
    """Within a pivot class [p0, 2*p0), messages grow by exactly 2 per extra
    rank (the two shift messages) — a direct corollary of the paper formula."""
    p0, _, extra = pivot(p)
    if extra:
        assert paper_message_count(p) == paper_message_count(p - 1) + 2


def test_microbatches_for_divisibility():
    """mb always divides the global batch, and B/mb stays DP-divisible when
    any divisor permits it."""
    import os

    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.dryrun import microbatches_for

    class M:
        def __init__(self, shape):
            self.axis_names = tuple(shape)
            self.shape = shape

    for dp, B in [(16, 256), (32, 256), (6, 252), (6, 256), (12, 240)]:
        mesh = M({"data": dp, "model": 1})
        for arch in ("qwen2.5-32b", "llama3.2-1b", "mixtral-8x7b"):
            mb = microbatches_for(arch, B, mesh)
            assert B % mb == 0, (dp, B, arch, mb)
            if any(B % m == 0 and (B // m) % dp == 0 for m in range(1, B + 1)):
                pass  # a DP-divisible choice exists; implementation prefers it
