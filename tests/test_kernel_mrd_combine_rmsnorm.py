"""mrd_combine + rmsnorm kernels vs oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.collectives.compression import quantize
from repro.kernels.mrd_combine.ops import mrd_combine
from repro.kernels.mrd_combine.ref import mrd_combine_ref
from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,bn", [(1024, 512), (4096, 1024), (2048, 2048)])
def test_mrd_combine_matches_ref(dtype, n, bn):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    q, s = quantize(g)
    out = mrd_combine(x, q, s, bn=bn, interpret=True)
    ref = mrd_combine_ref(x, q, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2
    )


def test_mrd_combine_equals_collective_receive_math():
    """kernel(x, quantize(g)) == x + dequant(quantize(g)) — the exact op the
    compressed reduce-scatter performs per stage."""
    from repro.collectives.compression import dequantize

    x = jnp.linspace(-2, 2, 512, dtype=jnp.float32)
    g = jnp.sin(jnp.arange(512, dtype=jnp.float32))
    q, s = quantize(g)
    out = mrd_combine(x, q, s, bn=512, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x + dequantize(q, s)), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,bt", [(64, 128, 32), (100, 256, 64), (16, 512, 16)])
def test_rmsnorm_matches_ref(dtype, T, d, bt):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (T, d), dtype) * 3
    w = jax.random.normal(ks[1], (d,), jnp.float32) * 0.1
    out = rmsnorm_kernel(x, w, bt=bt, interpret=True)
    ref = rmsnorm_ref(x, w)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol)


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rmsnorm as model_rmsnorm

    x = jax.random.normal(jax.random.PRNGKey(2), (8, 4, 64), jnp.float32)
    w = jnp.full((64,), 0.05, jnp.float32)
    ref = model_rmsnorm(x, w)
    out = rmsnorm_kernel(x.reshape(-1, 64), w, bt=8, interpret=True).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@given(
    nblocks=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
@settings(max_examples=20, deadline=None)
def test_mrd_combine_property(nblocks, seed):
    n = nblocks * 256
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n,), jnp.float32) * 10
    g = jax.random.normal(ks[1], (n,), jnp.float32) * 5
    q, s = quantize(g)
    out = mrd_combine(x, q, s, bn=n, interpret=True)
    # quantization error bound: |err| <= amax_block / 254 per element
    err = np.asarray(out) - (np.asarray(x) + np.asarray(g))
    bound = np.repeat(np.abs(np.asarray(g).reshape(-1, 256)).max(1), 256) / 254 + 1e-6
    assert np.all(np.abs(err) <= bound * 1.01)
