"""int8 (blockwise-quantized) KV cache: decode accuracy vs fp cache."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2.5-32b", "mixtral-8x7b"])
def test_int8_cache_decode_close_to_fp(arch):
    cfg = registry.get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    c16 = transformer.init_cache(cfg, B, S)
    c8 = transformer.init_cache(cfg8, B, S)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    assert c16["k"].dtype != jnp.int8
    for i in range(S):
        l16, c16 = transformer.forward_decode(params, toks[:, i], c16, jnp.int32(i), cfg)
        l8, c8 = transformer.forward_decode(params, toks[:, i], c8, jnp.int32(i), cfg8)
        rel = np.max(np.abs(np.asarray(l8) - np.asarray(l16))) / (
            np.max(np.abs(np.asarray(l16))) + 1e-9
        )
        assert rel < 0.06, f"{arch} step {i}: rel err {rel}"


def test_int8_cache_greedy_tokens_match():
    """Greedy decode paths agree on argmax tokens (quantization noise below
    decision boundaries for a typical run)."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    B, steps = 2, 12
    c16 = transformer.init_cache(cfg, B, steps + 1)
    c8 = transformer.init_cache(cfg8, B, steps + 1)
    t16 = t8 = jnp.array([5, 9], jnp.int32)
    agree = 0
    for i in range(steps):
        l16, c16 = transformer.forward_decode(params, t16, c16, jnp.int32(i), cfg)
        l8, c8 = transformer.forward_decode(params, t8, c8, jnp.int32(i), cfg8)
        t16 = jnp.argmax(l16, -1).astype(jnp.int32)
        t8 = jnp.argmax(l8, -1).astype(jnp.int32)
        agree += int((t16 == t8).all())
    assert agree >= steps - 2, f"only {agree}/{steps} greedy steps agree"
