"""Differential coverage for the PR-3 ``interval`` (windowed) protocol:
device-executor ConvergenceMonitor == sim-executor protocol path, bit for
bit, across p in {2..9}.

The existing plans matrix proves device==sim for raw collectives
(schedule x op x transform); this closes the gap for the *windowed
protocol* layered on top — per-rank window latching
(``monitor_contribution``) composed with the staged non-blocking MRD
reduction — which is exactly the code the training loop runs on device
and the asynchrony engine runs in sim.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=9"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.asynchrony.protocols import (
        RES_INIT, ConvergenceMonitor, get_protocol)
    from repro.collectives import plans

    W = 4
    THR = 0.08
    rng = np.random.default_rng(0)

    for p in range(2, 10):
        mesh = compat.make_mesh((p,), ("r",), devices=jax.devices()[:p])
        mon = ConvergenceMonitor(axis_name="r", threshold=THR,
                                 mode="interval", window=W)
        cycle = plans.allreduce_plan(schedule="mrd", p=p, op="max").cycle_length()
        T = 5 * cycle + W + 8
        # per-rank metrics decay below THR so `done` flips inside the run
        metrics = (rng.uniform(0.8, 1.2, (T, p)) * (0.6 ** np.arange(T))[:, None]
                   ).astype(np.float32)

        # ---- device: the training-loop monitor inside shard_map ----
        mon0 = mon.init(varying=False)
        rows = jax.tree.map(lambda x: jnp.broadcast_to(x, (p,) + x.shape), mon0)

        def local(rows1, m1, i):
            st = jax.tree.map(lambda x: x[0], rows1)
            new, done, val = mon.step(st, m1[0], i)
            return jax.tree.map(lambda x: x[None], new), done[None], val[None]

        rspec = jax.tree.map(lambda _: P("r"), rows)
        dev_step = jax.jit(compat.shard_map(
            local, mesh=mesh,
            in_specs=(rspec, P("r"), P()),
            out_specs=(rspec, P("r"), P("r")),
            axis_names={"r"}, check_vma=False))
        dev_done, dev_val = [], []
        with mesh:
            for i in range(T):
                rows, done, val = dev_step(
                    rows, jnp.asarray(metrics[i]), jnp.int32(i))
                dev_done.append(np.asarray(done))
                dev_val.append(np.asarray(val))

        # ---- sim: the same protocol over the stacked sim executor ----
        proto = get_protocol("interval")
        plan = plans.allreduce_plan(schedule="mrd", p=p, op="max")
        assert plan.cycle_length() == cycle
        mstate = {"win": jnp.full((p, W), RES_INIT, jnp.float32)}
        nb = plan.init(jnp.full((p,), RES_INIT, jnp.float32))
        value = jnp.full((p,), RES_INIT, jnp.float32)
        done = jnp.zeros((p,), jnp.bool_)

        @jax.jit
        def sim_step(mstate, nb, value, done, m, i):
            mstate, contrib = jax.vmap(
                lambda ms, mt: proto.monitor_contribution(ms, mt, i, cycle)
            )(mstate, m)
            nb = plan.step(nb, contrib)
            value = jnp.where(nb["flag"], nb["result"], value)
            done = done | (nb["flag"] & (value < THR))
            return mstate, nb, value, done

        sim_done, sim_val = [], []
        for i in range(T):
            mstate, nb, value, done = sim_step(
                mstate, nb, value, done, jnp.asarray(metrics[i]), jnp.int32(i))
            sim_done.append(np.asarray(done))
            sim_val.append(np.asarray(value))

        dev_done, dev_val = np.stack(dev_done), np.stack(dev_val)
        sim_done, sim_val = np.stack(sim_done), np.stack(sim_val)
        assert np.array_equal(dev_val, sim_val), (
            f"p={p} certified-value divergence: "
            f"max {np.abs(dev_val - sim_val).max()}")
        assert np.array_equal(dev_done, sim_done), f"p={p} done divergence"
        assert dev_done[-1].all(), f"p={p}: run too short to certify"
        print(f"p={p} interval device==sim OK (certified at "
              f"tick {int(np.argmax(dev_done[:, 0]))})")

    print("INTERVAL-DIFFERENTIAL-PASSED")
    """
)


@pytest.mark.slow
def test_interval_monitor_device_sim_bit_agreement():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "INTERVAL-DIFFERENTIAL-PASSED" in proc.stdout
