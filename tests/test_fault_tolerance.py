"""Fault tolerance: checkpoint/restore, async save atomicity, failure
detection, and the elastic shrink path (dp=4 -> kill one -> dp=3, non-p2)."""

import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro.runtime.fault_tolerance import FailureDetector, HeartbeatConfig


def test_failure_detector_timeout():
    det = FailureDetector([0, 1, 2], HeartbeatConfig(timeout_s=10))
    for w in (0, 1, 2):
        det.heartbeat(w, now=0.0)
    det.heartbeat(0, now=50.0)
    det.heartbeat(1, now=50.0)
    assert det.failed(now=55.0) == [2]


def test_straggler_detection():
    cfg = HeartbeatConfig(straggler_factor=3.0, evict_after_straggler_steps=2)
    det = FailureDetector([0, 1, 2, 3], cfg)
    for t in range(10):
        for w in (0, 1, 2):
            det.heartbeat(w, now=t, step_time=1.0)
        det.heartbeat(3, now=t, step_time=10.0)  # 10x median
    det.stragglers()
    assert 3 in det.stragglers()


def test_checkpointer_roundtrip_and_gc(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
    for step in (1, 2, 3):
        ck.save(step, state, extra={"data": {"step": step}}, block=True)
    assert ck.latest_step() == 3
    assert ck.list_steps() == [2, 3]  # gc kept last 2
    out = ck.restore(3, state)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(state["a"]))
    assert ck.manifest(3)["extra"]["data"]["step"] == 3


_ELASTIC = textwrap.dedent(
    """
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat

    from repro.configs import registry
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig
    from repro.runtime.fault_tolerance import shrink_mesh, recover

    cfg = registry.get_smoke_config("llama3.2-1b")
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync="mrd_zero1", monitor=False,
        optimizer=OptimizerConfig(lr=5e-3, schedule="const", warmup_steps=0))

    ckdir = tempfile.mkdtemp()

    # ---- phase 1: dp=4 ----
    mesh4 = compat.make_mesh((4,), ("data",), devices=jax.devices()[:4],
                          axis_types=compat.default_axis_types(1))
    step4, init4, specs4, _ = step_lib.make_train_step(cfg, mesh4, tcfg)
    with mesh4:
        state = init4(jax.random.PRNGKey(0))
        state = jax.device_put(state, jax.tree.map(
            lambda s: NamedSharding(mesh4, s), specs4(state)))
        pipe = SyntheticPipeline(cfg, DataConfig(batch=12, seq_len=32, seed=0), mesh4)
        js = jax.jit(step4)
        losses = []
        for i in range(4):
            state, m = js(state, pipe.next_batch())
            losses.append(float(m["loss"]))
        ck = Checkpointer(ckdir)
        ck.save(int(state["step"]), state, extra={"data": pipe.state_dict()}, block=True)
    print("phase1 losses:", [round(x,3) for x in losses])

    # ---- failure: device 0 dies -> shrink to dp=3 (non-power-of-two!) ----
    mesh3, kept = shrink_mesh(mesh4, {0}, "data")
    assert mesh3.shape["data"] == 3, mesh3.shape

    # MRD-ZeRO-1 state is dp-major: rebuild step fns for the new mesh; the
    # flat opt shards are re-derived from the restored params (simplest safe
    # elastic policy: params + data position survive; moments restart).
    step3, init3, specs3, _ = step_lib.make_train_step(cfg, mesh3, tcfg)
    with mesh3:
        template = init3(jax.random.PRNGKey(0))
        shardings = jax.tree.map(lambda s: NamedSharding(mesh3, s), specs3(template))
        # restore params + step from checkpoint; re-init opt for new dp extent
        full = Checkpointer(ckdir).restore(
            Checkpointer(ckdir).latest_step(),
            jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), # noqa
                {"params": template["params"], "step": template["step"]}),
        )
        state3 = init3(jax.random.PRNGKey(0))
        state3["params"] = full["params"]
        state3["step"] = jnp.asarray(full["step"])
        # re-seed masters from restored params (bucketed repack for dp=3,
        # matching make_zero1's shard layout)
        from repro.distributed.step import zero1_masters_from_params
        state3["opt"]["master"] = zero1_masters_from_params(
            full["params"], mesh3, ("data",), bucket_bytes=tcfg.bucket_bytes)
        state3 = jax.device_put(state3, shardings)

        pipe3 = SyntheticPipeline(cfg, DataConfig(batch=12, seq_len=32, seed=0), mesh3)
        pipe3.load_state_dict(Checkpointer(ckdir).manifest(
            Checkpointer(ckdir).latest_step())["extra"]["data"])
        js3 = jax.jit(step3)
        losses3 = []
        for i in range(4):
            state3, m3 = js3(state3, pipe3.next_batch())
            losses3.append(float(m3["loss"]))
    print("phase2 (dp=3) losses:", [round(x,3) for x in losses3])
    # training continues from where it left off: loss stays on trend
    assert losses3[0] < losses[0], (losses, losses3)
    assert losses3[-1] <= losses3[0] + 0.05
    print("ELASTIC-RESTART-PASSED")
    """
)


@pytest.mark.slow
def test_elastic_shrink_restart():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _ELASTIC],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-5000:]}"
    assert "ELASTIC-RESTART-PASSED" in proc.stdout
