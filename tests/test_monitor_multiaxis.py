"""ConvergenceMonitor over a multi-axis DP domain (e.g. ("pod","data")).

A tuple ``axis_name`` used to flow into single-axis ``jax.lax.axis_size`` /
``ppermute`` and explode; the plan layer now chains the per-axis MRD
schedules into one stage list.  The in-process test runs on a (1,1) mesh
(single device); the subprocess runs a real (2,2) domain with exact-mode
latching semantics.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.detection import ConvergenceMonitor


@pytest.mark.parametrize("mode", ["inexact", "exact"])
def test_monitor_tuple_axes_single_device(mode):
    """Tuple axis_name must work (this used to raise on lax.axis_size)."""
    mesh = compat.make_mesh((1, 1), ("pod", "data"), devices=jax.devices()[:1])
    mon = ConvergenceMonitor(
        axis_name=("pod", "data"), threshold=1e-3, mode=mode
    )

    def run(metrics):
        def body(carry, m_and_i):
            m, i = m_and_i
            st, done, val = mon.step(carry, m, i)
            return st, (done, val)

        _, (dones, vals) = jax.lax.scan(
            body, mon.init(), (metrics, jnp.arange(metrics.shape[0]))
        )
        return dones[None], vals[None]

    series = jnp.geomspace(1.0, 1e-6, 12, dtype=jnp.float32)
    dones, vals = jax.jit(
        compat.shard_map(
            lambda s: run(s[0]),
            mesh=mesh,
            in_specs=P(("pod", "data")),
            out_specs=(P(("pod", "data")), P(("pod", "data"))),
        )
    )(series[None])
    assert bool(np.asarray(dones)[0, -1]), "monitor never detected"


def test_monitor_cycle_length_chains_axes():
    """The chained plan's cycle = sum of per-axis schedules (here 2 + 1)."""
    from repro.collectives import plans

    plan = plans.allreduce_plan(schedule="mrd", p=4)
    assert plan.cycle_length() == 2
    # device plans resolve sizes lazily; check via an equivalent chained sim
    from repro.collectives.schedules import allreduce_schedule

    assert len(allreduce_schedule(4)) + len(allreduce_schedule(2)) == 3


_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core.detection import ConvergenceMonitor

    mesh = compat.make_mesh((2, 2), ("pod", "data"), devices=jax.devices()[:4])
    mon = ConvergenceMonitor(axis_name=("pod", "data"), threshold=1e-3,
                             mode="exact")

    # chained cycle over (2, 2): 1 + 1 butterfly stages
    steps = 12
    # per-rank metric series: rank r contributes (r+1) * base(i); the exact
    # mode certifies the max over ranks of the step-latched values
    base = jnp.geomspace(1.0, 1e-6, steps, dtype=jnp.float32)

    def run(series):
        def body(carry, m_and_i):
            m, i = m_and_i
            st, done, val = mon.step(carry, m, i)
            return st, (done, val)
        _, (dones, vals) = jax.lax.scan(
            body, mon.init(), (series, jnp.arange(steps)))
        return dones[None], vals[None]

    ranks = jnp.arange(4, dtype=jnp.float32).reshape(2, 2) + 1.0
    series = ranks[..., None] * base  # [2, 2, steps]
    dones, vals = jax.jit(compat.shard_map(
        lambda s: run(s[0]), mesh=mesh,
        in_specs=P(("pod", "data")),
        out_specs=(P(("pod", "data")), P(("pod", "data")))))(
            series.reshape(4, steps))
    dones = np.asarray(dones).reshape(4, steps)
    vals = np.asarray(vals).reshape(4, steps)
    # every rank certifies the same (exact) global values
    assert np.array_equal(vals, np.broadcast_to(vals[:1], vals.shape))
    # the certified value equals max over ranks of a *single* step's metric:
    # 4x the base series at the latch step (rank 3's contribution)
    certified = np.unique(vals[0])
    certified = certified[certified < 1e29]
    base_np = np.asarray(base)
    for v in certified:
        assert np.isclose(4.0 * base_np, v, rtol=1e-5).any(), (
            f"{v} is not 4*base[k] for any latch step k")
    assert dones[:, -1].all(), "exact monitor never detected on (2,2) mesh"
    print("MULTIAXIS-MONITOR-PASSED")
    """
)


@pytest.mark.slow
def test_monitor_exact_mode_two_axis_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "MULTIAXIS-MONITOR-PASSED" in proc.stdout
