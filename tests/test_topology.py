"""Schedule structure + the paper's S2 cost claims (E1/E2)."""

import math

import pytest
from hypothesis_compat import given, settings, st

from repro.core import topology as T


@given(st.integers(min_value=1, max_value=4096))
def test_pivot(p):
    p0, mu0, extra = T.pivot(p)
    assert p0 == 2**mu0 <= p < 2 ** (mu0 + 1)
    assert extra == p - p0


@given(st.integers(min_value=1, max_value=257))
@settings(max_examples=60)
def test_paper_step_count(p):
    """E2: log2(p0)+2 steps; shifts vanish when p = 2^k (paper S4)."""
    sched = T.allreduce_schedule(p)
    assert len(sched) == T.paper_step_count(p)
    if T.is_power_of_two(p):
        assert all(st_.kind == "butterfly" for st_ in sched)
    else:
        assert sched[0].kind == "bshift" and sched[-1].kind == "fshift"


@given(st.integers(min_value=1, max_value=257))
@settings(max_examples=60)
def test_paper_message_count(p):
    """E1: p0*log2(p0) + 2(p - p0) messages per cycle."""
    assert T.schedule_messages(T.allreduce_schedule(p)) == T.paper_message_count(p)


@pytest.mark.parametrize("p", [2, 3, 4, 5, 7, 8, 12, 16, 24, 32])
def test_schedules_pair_validity(p):
    p0, _, extra = T.pivot(p)
    for sched in (
        T.allreduce_schedule(p),
        T.reduce_scatter_schedule(p),
        T.allgather_schedule(p),
    ):
        for stg in sched:
            srcs = [s for s, _ in stg.pairs]
            dsts = [d for _, d in stg.pairs]
            assert len(set(srcs)) == len(srcs), "duplicate sources"
            assert len(set(dsts)) == len(dsts), "duplicate destinations"
            assert all(0 <= r < p for r in srcs + dsts)
            if stg.kind in ("butterfly", "rs", "ag"):
                # butterfly pairs are symmetric (i <-> i^d)
                assert set(stg.pairs) == {(d, s) for s, d in stg.pairs}


@pytest.mark.parametrize("p", [5, 8, 12, 16, 24])
def test_rabenseifner_volume_beats_mrd_for_large_buffers(p):
    """The beyond-paper motivation: RS+AG moves ~2n per rank vs n*log2(p0).
    (Strict win requires log2(p0) >= 2; at p0 = 2 the two coincide.)"""
    n = 1 << 20
    mrd_vol = T.schedule_volume(T.allreduce_schedule(p), n)
    rab_vol = T.schedule_volume(T.rabenseifner_schedule(p), n)
    assert rab_vol < mrd_vol


@pytest.mark.parametrize("p", [4, 8, 16, 64])
def test_alpha_beta_model_crossover(p):
    """MRD (latency-optimal) wins small payloads; Rabenseifner wins large."""
    link = T.LinkModel.tpu_v5e_ici()
    small, large = 8, 1 << 28
    t_mrd_small = T.schedule_time(T.allreduce_schedule(p), small, link)
    t_rab_small = T.schedule_time(T.rabenseifner_schedule(p), small, link)
    assert t_mrd_small <= t_rab_small
    t_mrd_large = T.schedule_time(T.allreduce_schedule(p), large, link)
    t_rab_large = T.schedule_time(T.rabenseifner_schedule(p), large, link)
    assert t_rab_large < t_mrd_large


def test_volume_closed_form():
    # full-buffer stages: butterfly volume = p0*log2(p0)*n; shifts 2*extra*n
    for p in (5, 6, 7, 9, 16):
        p0, mu0, extra = T.pivot(p)
        n = 128
        vol = T.schedule_volume(T.allreduce_schedule(p), n)
        assert vol == (p0 * mu0 + 2 * extra) * n
