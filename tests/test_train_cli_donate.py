"""Regression: ``launch/train.py --grad-sync mrd_leaf`` on multi-device CPU
used to deadlock because the CLI donated the train state to jit
(``donate_argnums=(0,)``): the strategy's DP-replicated params share one
backing buffer across CPU devices, donating it fails one replica with
"Attempt to donate the same buffer twice in Execute()" and the remaining
replicas wait forever at the collective-permute rendezvous.  Donation is
now gated on the backend; this drives the actual CLI entry point
end-to-end (pre-fix it hung — the timeout is the regression assertion)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    from repro.launch.train import main

    loss = main([
        "--arch", "llama3.2-1b", "--smoke", "--steps", "2",
        "--batch", "4", "--seq", "16", "--dp", "4",
        "--grad-sync", "mrd_leaf", "--log-every", "1",
    ])
    assert loss == loss  # finite-ish: train ran to completion
    print("MRD-LEAF-CLI-DONE")
    """
)


@pytest.mark.slow
def test_mrd_leaf_cli_does_not_deadlock():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # pre-fix this hung forever; the timeout is the regression assertion
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-5000:]}"
    )
    assert "MRD-LEAF-CLI-DONE" in proc.stdout
