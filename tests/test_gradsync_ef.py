"""EF-SGD error feedback for the ``compressed`` grad-sync mode: the residual
accumulator's algebra, and a convergence curve where plain int8 quantization
stalls but error feedback recovers full convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives import plans
from repro.collectives.transforms import dequantize, ef_roundtrip, quantize


def test_ef_roundtrip_conserves_the_intended_send():
    """sendable + new_ef == grad + ef bit-exactly: nothing is ever lost,
    only delayed."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1024) * 10.0, jnp.float32)
    ef = jnp.asarray(rng.standard_normal(1024) * 0.01, jnp.float32)
    sendable, new_ef = ef_roundtrip(x, ef)
    np.testing.assert_array_equal(
        np.asarray(sendable + new_ef), np.asarray(x + ef)
    )
    # sendable is on the quantization grid: re-quantizing is lossless
    q, s = quantize(sendable)
    np.testing.assert_allclose(
        np.asarray(dequantize(q, s)), np.asarray(sendable), rtol=1e-6, atol=1e-7
    )


def test_ef_accumulates_sub_quantum_signal():
    """A constant gradient far below the block's quantization step is dropped
    forever without EF, but crosses the grid within ~amax/(254*g) steps
    with it."""
    n = 256
    big = jnp.zeros((n,), jnp.float32).at[0].set(1.0)  # sets amax -> step ~ 1/254
    tiny = jnp.full((n,), 1e-4, jnp.float32)  # far below 1/254
    g = big + tiny

    sent_plain = dequantize(*quantize(g))
    assert float(jnp.max(jnp.abs(sent_plain[1:]))) == 0.0  # dropped

    ef = jnp.zeros((n,), jnp.float32)
    delivered = jnp.zeros((n,), jnp.float32)
    for _ in range(60):  # 1e-4 * 60 > (1/127)/2: must cross the grid
        sendable, ef = ef_roundtrip(g, ef)
        delivered = delivered + sendable
    # the tiny coordinates were delivered after all — in whole quanta, so
    # the per-tick average is lumpy but unmistakably nonzero
    mean_tail = float(jnp.mean(delivered[1:])) / 60
    assert 0.5e-4 < mean_tail < 2e-4, mean_tail


def test_compressed_ef_beats_plain_compressed_convergence():
    """Distributed SGD through a fully-quantized int8 collective (the MRD
    butterfly quantizes *every* contribution at *every* stage — no rank's
    raw buffer leaks into the result), p=4, sim executor: an ill-scaled
    quadratic whose per-block gradients hide small coordinates under a
    large one.  Plain int8 quantization stalls well above the solution;
    the same run with the EF-SGD residual fold converges several times
    closer.  This is the same ``ef_roundtrip`` fold the ``compressed``
    grad-sync strategy runs per bucket (``gradsync/mrd_zero1.py``)."""
    p, n = 4, 1024  # n % 256 == 0 (int8 block alignment)
    rng = np.random.default_rng(0)
    # per-rank targets; each 256-block has one large coordinate so amax/127
    # dwarfs the rest of the block's gradient entries
    base = rng.uniform(0.5e-3, 1.5e-3, size=n).astype(np.float32)
    base[::256] = 1.0
    targets = jnp.asarray(
        np.stack([base * (1.0 + 0.1 * r) for r in range(p)]), jnp.float32
    )
    t_mean = jnp.mean(targets, axis=0)

    plan = plans.allreduce_plan(schedule="mrd", p=p, op="sum", transform="int8")
    lr = 0.2

    def train(use_ef, steps=150):
        x = jnp.zeros((n,), jnp.float32)
        ef = jnp.zeros((p, n), jnp.float32)
        for _ in range(steps):
            g = jnp.broadcast_to(x, (p, n)) - targets  # per-rank grads
            if use_ef:
                g, ef = jax.vmap(ef_roundtrip)(g, ef)
            mean_g = plan.run(g)[0] / p
            x = x - lr * mean_g
        return float(jnp.max(jnp.abs(x - t_mean)))

    err_plain = train(use_ef=False)
    err_ef = train(use_ef=True)
    assert err_ef < 0.4 * err_plain, (err_ef, err_plain)
    assert err_ef < 2.5e-4, err_ef


def test_trainconfig_wires_error_feedback_state():
    """The compressed strategy carries opt['ef'] iff error feedback is on
    (builder-level check; the multi-device trajectory runs in
    tests/test_train_distributed.py)."""
    from repro import compat
    from repro.configs import registry
    from repro.distributed import step as step_lib

    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = compat.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    for ef_on in (True, False):
        tcfg = step_lib.TrainConfig(
            grad_sync="compressed", monitor=False, error_feedback=ef_on
        )
        _, init_state, state_specs, _ = step_lib.make_train_step(cfg, mesh, tcfg)
        state = init_state(jax.random.PRNGKey(0))
        assert ("ef" in state["opt"]) == ef_on
        if ef_on:
            specs = state_specs(state)
            assert "ef" in specs["opt"]
            assert state["opt"]["ef"].ndim == 2
