"""Exhaustive correctness of the MRD executors (sim backend) for arbitrary p,
including non-powers-of-two — the paper's headline case."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import mrd
from repro.core.topology import pivot


def _stack(p, shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(-50, 50, size=(p, *shape)).astype(dtype))
    return jnp.asarray((rng.standard_normal((p, *shape)) * 10).astype(dtype))


@given(
    p=st.integers(min_value=1, max_value=33),
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(["sum", "max", "min"]),
)
@settings(max_examples=80, deadline=None)
def test_sim_allreduce_matches_reference(p, seed, op):
    x = _stack(p, (7,), np.float32, seed)
    out = mrd.sim_allreduce(x, op=op)
    ref = {"sum": x.sum(0), "max": x.max(0), "min": x.min(0)}[op]
    np.testing.assert_allclose(
        np.asarray(out), np.broadcast_to(ref, (p, 7)), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32, "bfloat16"])
@pytest.mark.parametrize("p", [3, 8, 13])
def test_sim_allreduce_dtypes(p, dtype):
    if dtype == "bfloat16":
        x = jnp.asarray(np.arange(p * 5).reshape(p, 5), jnp.bfloat16)
    else:
        x = _stack(p, (5,), dtype, 0)
    out = mrd.sim_allreduce(x, op="sum")
    np.testing.assert_allclose(
        np.asarray(out, np.float64),
        np.broadcast_to(np.asarray(x, np.float64).sum(0), (p, 5)),
        rtol=1e-2 if dtype == "bfloat16" else 1e-6,
    )


@given(
    p=st.integers(min_value=1, max_value=33),
    mult=st.integers(min_value=1, max_value=3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_sim_reduce_scatter_segments(p, mult, seed):
    p0, _, _ = pivot(p)
    n = p0 * mult
    x = _stack(p, (n,), np.float32, seed)
    out = np.asarray(mrd.sim_reduce_scatter(x))
    ref = np.asarray(x.sum(0))
    for i in range(p0):  # pivot ranks hold natural-order segments
        np.testing.assert_allclose(
            out[i], ref[i * mult : (i + 1) * mult], rtol=1e-5, atol=1e-4
        )


@given(
    p=st.integers(min_value=1, max_value=33),
    mult=st.integers(min_value=1, max_value=3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_sim_rabenseifner_allreduce(p, mult, seed):
    p0, _, _ = pivot(p)
    n = p0 * mult
    x = _stack(p, (n,), np.float32, seed)
    out = np.asarray(mrd.sim_rabenseifner_allreduce(x))
    ref = np.broadcast_to(np.asarray(x.sum(0)), (p, n))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


def test_sim_allreduce_multidim_and_pytree_shape():
    p = 6
    x = _stack(p, (3, 4), np.float32, 1)
    out = mrd.sim_allreduce(x, op="max")
    np.testing.assert_allclose(np.asarray(out), np.broadcast_to(x.max(0), (p, 3, 4)))


def test_sim_allreduce_jit_and_grad():
    """The collective is differentiable (needed if used inside training math)."""
    p = 5
    x = _stack(p, (4,), np.float32, 2)

    f = jax.jit(lambda v: mrd.sim_allreduce(v, op="sum").sum())
    g = jax.grad(lambda v: mrd.sim_allreduce(v, op="sum")[0].sum())(x)
    # d(sum_i x_i)/dx_j = 1 for every j contributing to row 0's total
    np.testing.assert_allclose(np.asarray(g), np.ones((p, 4)), rtol=1e-6)
    assert np.isfinite(float(f(x)))
