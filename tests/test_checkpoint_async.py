"""Async checkpointing semantics (DESIGN.md S16): non-blocking ``save``,
the tri-state ``block`` contract, stale-tmp crash recovery, writer-error
propagation, and the step/time save policies."""

from __future__ import annotations

import os
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import checkpointer as ckpt_lib  # noqa: E402
from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402


def _state(seed=0, n=64):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.standard_normal((n, n)).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal((n,)).astype(np.float32)),
        },
        "step": jnp.asarray(seed, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Stale-tmp sweep (crash recovery)
# ---------------------------------------------------------------------------

def test_stale_tmp_swept_on_construction(tmp_path):
    d = str(tmp_path)
    # a crash mid-write left a torn snapshot dir and a dangling pointer tmp
    os.makedirs(os.path.join(d, "step_7.tmp"))
    with open(os.path.join(d, "step_7.tmp", "arrays.npz"), "wb") as f:
        f.write(b"torn")
    with open(os.path.join(d, "LATEST.tmp"), "w") as f:
        f.write("7")
    ck = Checkpointer(d)
    assert not os.path.exists(os.path.join(d, "step_7.tmp"))
    assert not os.path.exists(os.path.join(d, "LATEST.tmp"))
    assert ck.list_steps() == []
    assert ck.latest_step() is None


def test_tmp_dirs_invisible_to_listing(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _state(3), block=True)
    # simulate a crash that left a *newer* torn snapshot behind
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert ck.list_steps() == [3]
    assert ck.latest_step() == 3
    # a fresh Checkpointer over the same dir sweeps it and still restores 3
    ck2 = Checkpointer(str(tmp_path))
    assert not os.path.exists(os.path.join(str(tmp_path), "step_9.tmp"))
    got = ck2.restore(3, _state(0))
    ref = _state(3)
    assert np.array_equal(np.asarray(got["params"]["w"]),
                          np.asarray(ref["params"]["w"]))


# ---------------------------------------------------------------------------
# block semantics: False / 'transfer' / True
# ---------------------------------------------------------------------------

def test_async_save_returns_before_write(tmp_path, monkeypatch):
    """block=False must return while the npz write is still pending."""
    gate = threading.Event()
    entered = threading.Event()
    real_savez = np.savez

    def slow_savez(path, **arrays):
        entered.set()
        assert gate.wait(timeout=30), "writer never released"
        real_savez(path, **arrays)

    monkeypatch.setattr(ckpt_lib.np, "savez", slow_savez)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), block=False)  # returns with the writer gated
    assert entered.wait(timeout=30)
    assert ck.list_steps() == []  # nothing published yet
    gate.set()
    ck.wait()
    assert ck.list_steps() == [1]
    assert ck.latest_step() == 1


def test_transfer_block_returns_before_write(tmp_path, monkeypatch):
    """block='transfer' waits for host materialization but NOT the write —
    the donation-safe point: the caller may reuse the device buffers."""
    gate = threading.Event()
    real_savez = np.savez

    def slow_savez(path, **arrays):
        assert gate.wait(timeout=30)
        real_savez(path, **arrays)

    monkeypatch.setattr(ckpt_lib.np, "savez", slow_savez)
    ck = Checkpointer(str(tmp_path))
    ck.save(2, _state(2), block="transfer")  # must not deadlock on the gate
    assert ck.list_steps() == []
    gate.set()
    ck.wait()
    assert ck.list_steps() == [2]


def test_blocking_save_round_trips_bitwise(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state(5)
    ck.save(5, state, extra={"data": {"cursor": 17}}, block=True)
    assert ck.latest_step() == 5
    got = ck.restore(5, jax.tree.map(np.asarray, state))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert ck.manifest(5)["extra"]["data"]["cursor"] == 17


def test_async_save_round_trips_bitwise_after_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state(6)
    ck.save(6, state, block=False)
    ck.wait()
    got = ck.restore(6, jax.tree.map(np.asarray, state))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_save_joins_previous_inflight_save(tmp_path, monkeypatch):
    """A second save never overtakes an in-flight one: save() joins first,
    so snapshots publish in issue order."""
    order = []
    real_savez = np.savez

    def tracking_savez(path, **arrays):
        order.append(os.path.basename(os.path.dirname(path)))
        real_savez(path, **arrays)

    monkeypatch.setattr(ckpt_lib.np, "savez", tracking_savez)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), block=False)
    ck.save(2, _state(2), block=False)
    ck.wait()
    assert order == ["step_1.tmp", "step_2.tmp"]
    assert ck.list_steps() == [1, 2]


# ---------------------------------------------------------------------------
# Writer-error propagation
# ---------------------------------------------------------------------------

def test_writer_error_surfaces_on_wait(tmp_path, monkeypatch):
    def boom(path, **arrays):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_lib.np, "savez", boom)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1), block=False)
    with pytest.raises(OSError, match="disk full"):
        ck.wait()
    # the error is consumed — the checkpointer stays usable
    ck.wait()
    assert ck.list_steps() == []


def test_writer_error_surfaces_on_transfer_block(tmp_path, monkeypatch):
    """block='transfer' re-raises an error that happened before the
    transfer barrier (e.g. a leaf that fails to materialize)."""

    def boom(*a, **k):
        raise RuntimeError("d2h failed")

    monkeypatch.setattr(ckpt_lib.np, "asarray", boom)
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(RuntimeError, match="d2h failed"):
        ck.save(1, _state(1), block="transfer")


# ---------------------------------------------------------------------------
# Save policies: step cadence + wall-time cadence
# ---------------------------------------------------------------------------

def test_should_save_step_policy(tmp_path):
    ck = Checkpointer(str(tmp_path), save_every_steps=10)
    assert [s for s in range(1, 31) if ck.should_save(s)] == [10, 20, 30]


def test_should_save_time_policy(tmp_path):
    now = [0.0]
    ck = Checkpointer(
        str(tmp_path), save_every_seconds=60.0, clock=lambda: now[0])
    assert not ck.should_save(1)
    now[0] = 59.0
    assert not ck.should_save(2)
    now[0] = 61.0
    assert ck.should_save(3)
    # a save resets the clock origin
    ck.save(3, _state(3), block=True)
    assert not ck.should_save(4)
    now[0] = 130.0
    assert ck.should_save(5)


def test_should_save_either_policy_fires(tmp_path):
    now = [0.0]
    ck = Checkpointer(
        str(tmp_path), save_every_steps=100, save_every_seconds=30.0,
        clock=lambda: now[0])
    assert not ck.should_save(7)
    assert ck.should_save(100)  # step cadence
    now[0] = 31.0
    assert ck.should_save(7)  # time cadence


def test_maybe_save_respects_policy(tmp_path):
    ck = Checkpointer(str(tmp_path), save_every_steps=2)
    assert not ck.maybe_save(1, _state(1), block=True)
    assert ck.maybe_save(2, _state(2), block=True)
    assert ck.list_steps() == [2]


def test_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s), block=True)
    assert ck.list_steps() == [3, 4]
    assert ck.latest_step() == 4
