"""Optimizer: schedules, tree-vs-vector form equivalence, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizer as O


def test_wsd_schedule_shape():
    ocfg = O.OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                             total_steps=100, wsd_decay_frac=0.2)
    lrs = [float(O.schedule_lr(ocfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # end of warmup
    assert all(abs(l - 1.0) < 1e-6 for l in lrs[10:80])  # stable plateau
    assert lrs[90] < 0.6                       # decaying tail
    assert lrs[100] < 1e-6                     # decayed to ~0


def test_cosine_schedule_endpoints():
    ocfg = O.OptimizerConfig(lr=2.0, schedule="cosine", warmup_steps=0, total_steps=50)
    assert abs(float(O.schedule_lr(ocfg, jnp.asarray(0))) - 2.0) < 1e-5
    assert float(O.schedule_lr(ocfg, jnp.asarray(50))) < 1e-5


@pytest.mark.parametrize("name", ["adamw", "lion", "sgd"])
def test_tree_and_vector_forms_agree(name):
    """apply_update (tree) and apply_update_vector (ZeRO shard) produce the
    same params for the same flat problem."""
    ocfg = O.OptimizerConfig(name=name, lr=1e-2, schedule="const",
                             warmup_steps=0, weight_decay=0.1)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (32,), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (32,), jnp.float32)

    tree_opt = O.init_opt_state({"w": w})
    step = jnp.zeros((), jnp.int32)
    p_tree, opt_tree = O.apply_update({"w": g}, tree_opt, ocfg, step, jnp.float32)

    vec_opt = O.init_opt_vector(32)
    vec_opt["master"] = w
    m_vec, _ = O.apply_update_vector(g, vec_opt, ocfg, step)
    np.testing.assert_allclose(np.asarray(p_tree["w"]), np.asarray(m_vec), rtol=1e-6)


def test_adamw_converges_quadratic():
    ocfg = O.OptimizerConfig(name="adamw", lr=0.1, schedule="const",
                             warmup_steps=0, weight_decay=0.0)
    opt = O.init_opt_vector(4)
    opt["master"] = jnp.asarray([5.0, -3.0, 2.0, 8.0])
    target = jnp.asarray([1.0, 1.0, -1.0, 0.0])
    m = opt["master"]
    for s in range(300):
        g = m - target
        m, opt = O.apply_update_vector(g, opt, ocfg, jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(m), np.asarray(target), atol=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    # ||g|| = sqrt(4*9 + 9*16) = sqrt(180)
    clipped, gnorm = O.clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(gnorm), np.sqrt(180.0), rtol=1e-6)
    sq = sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(clipped))
    np.testing.assert_allclose(np.sqrt(sq), 1.0, rtol=1e-5)
    same, _ = O.clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)
