"""Pallas selective-scan kernel vs oracle + vs the model's chunked scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


def _inputs(seed, B, S, D, N, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    # decay in (0, 1): well-conditioned recurrence like exp(dt*A)
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D, N))).astype(dtype)
    bx = (jax.random.normal(ks[1], (B, S, D, N)) * 0.1).astype(dtype)
    cs = jax.random.normal(ks[2], (B, S, N), dtype)
    return decay, bx, cs


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,D,N,bd,chunk",
    [
        (1, 64, 16, 8, 16, 16),
        (2, 128, 32, 16, 16, 32),
        (1, 96, 64, 4, 32, 64),  # S not multiple of chunk -> padding path
    ],
)
def test_kernel_matches_ref(dtype, B, S, D, N, bd, chunk):
    decay, bx, cs = _inputs(0, B, S, D, N, dtype)
    out = selective_scan(decay, bx, cs, bd=bd, chunk=chunk, interpret=True)
    ref, _ = selective_scan_ref(decay, bx, cs)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


@given(
    s=st.integers(4, 80),
    d=st.sampled_from([8, 16]),
    n=st.sampled_from([4, 8]),
    chunk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_kernel_matches_ref_property(s, d, n, chunk, seed):
    decay, bx, cs = _inputs(seed, 1, s, d, n)
    out = selective_scan(decay, bx, cs, bd=d, chunk=chunk, interpret=True)
    ref, _ = selective_scan_ref(decay, bx, cs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_kernel_matches_model_recurrence():
    """The kernel computes the exact recurrence the mamba1 block uses, on
    inputs produced by the model's own SSM-input projection."""
    from repro.configs import registry
    from repro.models.ssm import _mamba1_ssm_inputs, mamba1_init

    cfg = registry.get_smoke_config("falcon-mamba-7b")
    p = mamba1_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S, di = 2, 48, cfg.d_inner
    x1 = jax.random.normal(jax.random.PRNGKey(1), (B, S, di), jnp.float32)
    decay, bx, cs = _mamba1_ssm_inputs(p, x1, cfg)
    y_ref, _ = selective_scan_ref(decay, bx, cs)
    y_kernel = selective_scan(decay, bx, cs, bd=di, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
