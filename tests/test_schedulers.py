"""Direct unit tests for the admission schedulers (``SCHEDULERS``).

Schedulers were previously exercised only through engine integration
runs; these tests pin their contracts in isolation: ordering and
tie-breaking per policy, the free-slot zip (lowest slots first, at most
``len(free_slots)`` admissions), the ``eligible`` pass-over gate, the
``name:arg`` spec parsing, and ``sla_edf``'s age-based anti-starvation
promotion (the bugfix: a sustained stream of tight-deadline traffic must
not starve no-SLA batch requests indefinitely).
"""

import pytest

from repro.serving import SCHEDULERS, get_scheduler
from repro.serving.schedulers import SlaEdfScheduler


class R:
    def __init__(self, id, arrival, priority=0, sla=None):
        self.id, self.arrival = id, arrival
        self.priority, self.sla = priority, sla

    def __repr__(self):
        return f"R{self.id}"


def ids(pairs):
    return [r.id for r, _ in pairs]


def slots(pairs):
    return [s for _, s in pairs]


# ---------------------------------------------------------------------------
# ordering + tie-breaking
# ---------------------------------------------------------------------------


def test_fcfs_orders_by_arrival_then_id():
    q = [R(3, 5), R(1, 2), R(2, 2), R(0, 9)]
    out = get_scheduler("fcfs").order(q, now=10)
    assert [r.id for r in out] == [1, 2, 3, 0]  # arrival, ties by id


def test_priority_orders_by_priority_then_fcfs():
    q = [R(0, 1), R(1, 5, priority=2), R(2, 3, priority=2), R(3, 0, priority=1)]
    out = get_scheduler("priority").order(q, now=6)
    # priority desc; among equal priority, arrival asc
    assert [r.id for r in out] == [2, 1, 3, 0]


def test_sla_edf_deadline_order_and_no_sla_last():
    q = [R(0, 0), R(1, 4, sla=10), R(2, 0, sla=8), R(3, 1)]
    out = get_scheduler("sla_edf").order(q, now=5)
    # deadlines: r2 at 8, r1 at 14; no-SLA r0/r3 sort last, FCFS among
    # themselves
    assert [r.id for r in out] == [2, 1, 0, 3]


def test_sla_edf_deadline_tie_breaks_by_arrival_then_id():
    q = [R(5, 4, sla=6), R(4, 2, sla=8), R(6, 2, sla=8)]
    out = get_scheduler("sla_edf").order(q, now=5)
    assert [r.id for r in out] == [4, 6, 5]  # all deadline 10: arrival, id


# ---------------------------------------------------------------------------
# select(): free-slot zip + eligibility pass-over
# ---------------------------------------------------------------------------


def test_select_assigns_lowest_slots_in_order_deterministically():
    q = [R(0, 3), R(1, 1), R(2, 2)]
    sched = get_scheduler("fcfs")
    out = sched.select(q, [7, 2, 5], now=4)
    assert ids(out) == [1, 2, 0]
    assert slots(out) == [2, 5, 7]  # lowest-numbered slots first
    # pure function of (queue, slots, now): replays identically
    assert ids(sched.select(list(q), [7, 2, 5], now=4)) == [1, 2, 0]


def test_select_admits_at_most_free_slots():
    q = [R(i, i) for i in range(5)]
    out = get_scheduler("fcfs").select(q, [0, 1], now=9)
    assert ids(out) == [0, 1]


def test_select_empty_queue_or_no_slots():
    assert get_scheduler("fcfs").select([], [0], now=0) == []
    assert get_scheduler("fcfs").select([R(0, 0)], [], now=0) == []


def test_select_eligible_gate_passes_over_blocked_requests():
    q = [R(0, 0), R(1, 1), R(2, 2)]
    out = get_scheduler("fcfs").select(
        q, [0, 1], now=5, eligible=lambda r: r.id != 0
    )
    # r0 is blocked (quota / cache budget): the slot goes to the next
    # request in scheduling order instead of being wasted
    assert ids(out) == [1, 2]
    assert slots(out) == [0, 1]


# ---------------------------------------------------------------------------
# name:arg specs
# ---------------------------------------------------------------------------


def test_unknown_scheduler_raises():
    with pytest.raises(ValueError, match="unknown scheduler"):
        get_scheduler("deadline")


def test_spec_arg_builds_parameterized_instance():
    s = get_scheduler("sla_edf:7")
    assert isinstance(s, SlaEdfScheduler) and s.max_wait == 7
    # the registry default is untouched
    assert SCHEDULERS["sla_edf"].max_wait == 64


def test_spec_arg_rejected_by_parameterless_schedulers():
    with pytest.raises(ValueError, match="takes no"):
        get_scheduler("fcfs:3")


def test_sla_edf_rejects_nonpositive_max_wait():
    with pytest.raises(ValueError, match="max_wait"):
        get_scheduler("sla_edf:0")


# ---------------------------------------------------------------------------
# anti-starvation promotion (bugfix)
# ---------------------------------------------------------------------------


def test_sla_edf_promotes_starved_request_to_front():
    batch = R(0, 0)  # no SLA: EDF alone would sort it last forever
    q = [batch] + [R(i, 10 + i, sla=2) for i in range(1, 4)]
    s = get_scheduler("sla_edf:8")
    assert [r.id for r in s.order(q, now=7)][-1] == 0  # not yet promoted
    out = s.order(q, now=8)  # waited max_wait -> promoted
    assert out[0].id == 0
    # promoted requests rank oldest-first, ahead of every live deadline
    q2 = q + [R(9, 1)]
    out2 = s.order(q2, now=20)
    assert [r.id for r in out2[:2]] == [0, 9]


def test_sla_edf_promotion_applies_to_slad_requests_too():
    old = R(0, 0, sla=100)  # far deadline but ancient
    q = [old] + [R(i, 63 + i, sla=1) for i in range(1, 4)]
    out = get_scheduler("sla_edf").order(q, now=64)  # default max_wait=64
    assert out[0].id == 0
