"""Device-executor (shard_map + ppermute) equivalence with the sim executor.

These run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps seeing exactly one device.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.core import mrd, nonblocking, detection
    from repro.core.topology import pivot

    def shard_map(f, *, mesh, in_specs, out_specs):
        return compat.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    def mesh_for(p):
        return compat.make_mesh((p,), ("r",), devices=jax.devices()[:p],
                                axis_types=compat.default_axis_types(1))

    rng = np.random.default_rng(0)

    # --- allreduce: device == sim == reference, all ops, non-p2 included ---
    for p in [1, 2, 3, 5, 6, 7, 8]:
        mesh = mesh_for(p)
        x = jnp.asarray(rng.standard_normal((p, 6)).astype(np.float32))
        for op in ["sum", "max", "min"]:
            dev = jax.jit(shard_map(
                lambda v: mrd.allreduce(v[0], "r", op=op)[None],
                mesh=mesh, in_specs=P("r"), out_specs=P("r")))(x)
            sim = mrd.sim_allreduce(x, op=op)
            np.testing.assert_allclose(np.asarray(dev), np.asarray(sim), rtol=1e-5)
    print("allreduce-equivalence OK")

    # --- rabenseifner + reduce_scatter/allgather ---
    for p in [2, 3, 5, 8]:
        p0, _, _ = pivot(p)
        n = p0 * 4
        mesh = mesh_for(p)
        x = jnp.asarray(rng.standard_normal((p, n)).astype(np.float32))
        dev = jax.jit(shard_map(
            lambda v: mrd.rabenseifner_allreduce(v[0], "r")[None],
            mesh=mesh, in_specs=P("r"), out_specs=P("r")))(x)
        np.testing.assert_allclose(
            np.asarray(dev), np.broadcast_to(np.asarray(x.sum(0)), (p, n)),
            rtol=1e-4, atol=1e-4)
    print("rabenseifner-device OK")

    # --- tree_allreduce_flat over a pytree (grad-sync path) ---
    p = 6
    mesh = mesh_for(p)
    tree = {"a": jnp.asarray(rng.standard_normal((p, 3, 2)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((p, 5)), jnp.float32)}
    dev = jax.jit(shard_map(
        lambda t: jax.tree.map(
            lambda l: l[None],
            mrd.tree_allreduce_flat(jax.tree.map(lambda l: l[0], t), "r")),
        mesh=mesh, in_specs=P("r"), out_specs=P("r")))(tree)
    np.testing.assert_allclose(np.asarray(dev["a"][0]), np.asarray(tree["a"].sum(0)), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dev["b"][3]), np.asarray(tree["b"].sum(0)), rtol=1e-4)
    print("tree-flat OK")

    # --- multi-bucket pipelined path on device == sim, bit-for-bit ---
    from repro.collectives import plans as plan_lib
    for schedule in ["mrd", "rabenseifner"]:
        dev_out = jax.jit(shard_map(
            lambda t: jax.tree.map(
                lambda l: l[None],
                mrd.tree_allreduce_flat(jax.tree.map(lambda l: l[0], t), "r",
                                        schedule=schedule, bucket_bytes=16)),
            mesh=mesh, in_specs=P("r"), out_specs=P("r")))(tree)
        sim_out = plan_lib.tree_allreduce(tree, schedule=schedule, p=p,
                                          bucket_bytes=16)
        for kd, ks in zip(jax.tree.leaves(dev_out), jax.tree.leaves(sim_out)):
            assert np.array_equal(np.asarray(kd), np.asarray(ks)), schedule
    print("tree-bucketed device==sim OK")

    # --- hierarchical allreduce over a 2D mesh (pod-aware) ---
    mesh2 = compat.make_mesh((2, 4), ("pod", "data"), devices=jax.devices()[:8],
                          axis_types=compat.default_axis_types(2))
    n = 8
    x = jnp.asarray(rng.standard_normal((8, n)).astype(np.float32))
    def hier(v):
        return mrd.hierarchical_allreduce(v[0], "data", "pod")[None]
    dev = jax.jit(shard_map(
        hier, mesh=mesh2,
        in_specs=P(("pod", "data")),
        out_specs=P(("pod", "data"))))(x.reshape(8, n))
    np.testing.assert_allclose(
        np.asarray(dev), np.broadcast_to(np.asarray(x.sum(0)), (8, n)), rtol=1e-4)
    print("hierarchical OK")

    # --- non-blocking statechart on device ---
    p = 5
    mesh = mesh_for(p)
    x = jnp.arange(p, dtype=jnp.float32) + 1.0
    def drive(v):
        val = v[0]
        st = nonblocking.init(val)
        for _ in range(nonblocking.cycle_length(p)):
            st = nonblocking.step(st, val, axis_name="r", op="max")
        return st["result"][None], st["flag"][None]
    res, flag = jax.jit(shard_map(
        drive, mesh=mesh, in_specs=P("r"), out_specs=(P("r"), P("r"))))(x)
    assert np.allclose(np.asarray(res), float(p)), res
    assert np.all(np.asarray(flag)), flag
    print("nonblocking-device OK")

    # --- ConvergenceMonitor on device: decreasing metric detects ---
    mon = detection.ConvergenceMonitor(axis_name="r", threshold=1e-3, mode="inexact")
    def run_monitor(metrics):
        # metrics: [steps] per-rank series
        def body(carry, m_and_i):
            m, i = m_and_i
            st, done, val = mon.step(carry, m, i)
            return st, (done, val)
        st, (dones, vals) = jax.lax.scan(
            body, mon.init(),
            (metrics, jnp.arange(metrics.shape[0])))
        return dones[None], vals[None]
    steps = 40
    series = jnp.geomspace(1.0, 1e-6, steps, dtype=jnp.float32)
    series = jnp.broadcast_to(series, (p, steps))
    dones, vals = jax.jit(shard_map(
        lambda s: run_monitor(s[0]), mesh=mesh, in_specs=P("r"),
        out_specs=(P("r"), P("r"))))(series)
    assert bool(np.asarray(dones)[0, -1]), "monitor never detected"
    print("monitor-device OK")
    print("ALL-DEVICE-TESTS-PASSED")
    """
)


@pytest.mark.slow
def test_device_executor_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL-DEVICE-TESTS-PASSED" in proc.stdout
