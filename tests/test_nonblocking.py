"""The staged (non-blocking) MRD Allreduce state machine — paper Fig. 4."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nonblocking as nb


@pytest.mark.parametrize("p", [1, 2, 3, 5, 8, 11, 16])
def test_cycle_produces_reduction(p):
    rng = np.random.default_rng(p)
    x = jnp.asarray(rng.standard_normal((p, 4)).astype(np.float32))
    st = nb.init(x)
    clen = nb.cycle_length(p)
    for i in range(clen):
        st = nb.step(st, x, p=p, op="max")
        assert bool(st["flag"]) == (i == clen - 1)
    np.testing.assert_allclose(
        np.asarray(st["result"]), np.broadcast_to(np.asarray(x).max(0), (p, 4)),
        rtol=1e-6,
    )
    assert int(st["cycles"]) == 1


@pytest.mark.parametrize("p", [3, 8])
def test_relatch_between_cycles(p):
    """Values contributed mid-cycle are ignored; each cycle reduces the values
    latched at its start (the paper's statechart semantics)."""
    clen = nb.cycle_length(p)
    v0 = jnp.arange(p, dtype=jnp.float32)
    v_mid = jnp.full((p,), 1e9, jnp.float32)
    v1 = -jnp.arange(p, dtype=jnp.float32)

    st = nb.init(v0)
    for i in range(clen):
        st = nb.step(st, v0 if i == 0 else v_mid, p=p, op="max")
    np.testing.assert_allclose(np.asarray(st["result"]), float(p - 1))

    for i in range(clen):
        st = nb.step(st, v1 if i == 0 else v_mid, p=p, op="max")
    np.testing.assert_allclose(np.asarray(st["result"]), 0.0)
    assert int(st["cycles"]) == 2


def test_cycle_length_matches_paper():
    for p, expect in [(1, 1), (2, 1), (4, 2), (5, 4), (8, 3), (12, 5), (16, 4)]:
        assert nb.cycle_length(p) == expect


def test_staged_equals_blocking():
    p = 7
    x = jnp.asarray(np.random.default_rng(0).standard_normal((p, 3)), jnp.float32)
    out = nb.run_blocking(x, p=p, op="min")
    np.testing.assert_allclose(
        np.asarray(out), np.broadcast_to(np.asarray(x).min(0), (p, 3)), rtol=1e-6
    )


def test_step_is_jittable():
    p = 6
    x = jnp.ones((p,), jnp.float32)
    st = nb.init(x)
    step = jax.jit(lambda s, v: nb.step(s, v, p=p, op="sum"))
    for _ in range(nb.cycle_length(p)):
        st = step(st, x)
    np.testing.assert_allclose(np.asarray(st["result"]), float(p))
