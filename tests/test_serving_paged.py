"""Block-paged serving (repro.serving.paged, DESIGN.md S14).

Core claims under test:

1. **Bit-equivalence** — paged decode (block tables, slot recycling, mixed
   admission, prefix sharing) produces token-for-token identical outputs
   to the contiguous pool AND to decoding each request alone in a static
   batch, at termination agreement dp ∈ {1, 2, 3}, on a dense and a hybrid
   (SSM+attention) arch.  The mechanism: the paged step gathers each
   slot's blocks into exactly the contiguous layout and runs the unchanged
   decode vmap, so the jaxpr — and therefore every bit — matches.
2. **Prefix sharing** — identical system prefixes map to the *same*
   physical blocks (stored once, refcounted); sharers retire
   independently; shared blocks are never written by a sharer's decode.
3. **Block accounting** — recycling returns every block to the allocator;
   admission is backpressured (a request waits in the queue when the pool
   is out of blocks) instead of deadlocking or evicting.
4. **Capacity honesty** — a slot frozen at its reserved capacity is
   force-retired and surfaced in ``summary()['forced_at_capacity']``
   rather than silently spinning against its budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import registry
from repro.distributed import step as step_lib
from repro.models import transformer
from repro.serving import (
    PagedDecodePool,
    Request,
    ServeConfig,
    ServeEngine,
    make_workload,
)


def _mesh(n=1):
    return compat.make_mesh(
        (n,), ("data",), devices=jax.devices()[:n],
        axis_types=compat.default_axis_types(1),
    )


def _solo_decode(cfg, mesh, params, prompt, max_new):
    """The request decoded alone in a static batch (the PR-4 serve path)."""
    serve_step, _ = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)
    jstep, jprefill = jax.jit(serve_step), jax.jit(prefill_step)
    S = int(prompt.shape[0])
    with mesh:
        cache = transformer.init_cache(cfg, 1, S + max_new + 1)
        logits, cache = jprefill(params, jnp.asarray(prompt[None]), cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        for k in range(max_new - 1):
            logits, cache = jstep(
                params, jnp.asarray(toks[-1:], jnp.int32), cache,
                jnp.int32(S + k),
            )
            toks.append(int(jnp.argmax(logits, -1)[0]))
    return np.asarray(toks, np.int32)


def _requests(cfg, *, seed=3, share_prefix=0):
    """5 requests over 2 slots: recycling forced, admissions mid-decode,
    mixed lengths.  ``share_prefix`` tokens are common to all prompts."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab, size=share_prefix)
    lens = (1, 2, 4, 3, 2) if share_prefix else (3, 5, 8, 5, 3)
    prompts = [
        np.concatenate([pre, rng.integers(0, cfg.vocab, size=L)]).astype(
            np.int64
        )
        for L in lens
    ]
    max_new = [6, 4, 7, 5, 6]
    return [
        Request(id=i, arrival=[0, 0, 2, 5, 7][i], prompt=prompts[i],
                max_new=max_new[i])
        for i in range(5)
    ]


def _run(workload_name, cfg, mesh, reqs, *, dp=1, **kw):
    wl = make_workload(
        workload_name, cfg=cfg, mesh=mesh, slots=2, max_len=24,
        max_prompt_len=12, seed=0, **kw,
    )
    eng = ServeEngine(wl, ServeConfig(dp=dp))
    res = eng.run(list(reqs))
    return wl, eng, res


# ---------------------------------------------------------------------------
# 1. Paged == contiguous == solo, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dp", [1, 2, 3])
def test_paged_matches_contiguous_and_solo_dense(dp):
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    reqs = _requests(cfg)
    wl_c, _, res_c = _run("llm_decode", cfg, mesh, reqs, dp=dp)
    wl_p, _, res_p = _run("llm_decode_paged", cfg, mesh, reqs, dp=dp,
                          block_size=8)
    for r in reqs:
        np.testing.assert_array_equal(res_c[r.id].output, res_p[r.id].output)
        solo = _solo_decode(
            cfg, mesh, wl_c.params, np.asarray(r.prompt, np.int64),
            wl_c.clamp_max_new(r),
        )
        np.testing.assert_array_equal(res_p[r.id].output, solo)
    # paging is strictly denser per byte at equal capacity is a bench
    # claim; here just assert the accounting drained cleanly
    assert wl_p.pool.allocator.used_blocks == 0
    wl_p.pool.allocator.check()


@pytest.mark.slow
@pytest.mark.parametrize("dp", [1, 3])
def test_paged_matches_contiguous_hybrid(dp):
    """Hybrid (Mamba + attention): attn leaves paged, SSM state per-slot."""
    cfg = registry.get_smoke_config("zamba2-2.7b")
    mesh = _mesh()
    reqs = _requests(cfg)
    _, _, res_c = _run("llm_decode", cfg, mesh, reqs, dp=dp)
    _, _, res_p = _run("llm_decode_paged", cfg, mesh, reqs, dp=dp,
                       block_size=8)
    for r in reqs:
        np.testing.assert_array_equal(res_c[r.id].output, res_p[r.id].output)


@pytest.mark.slow
def test_paged_matches_contiguous_multidevice():
    """Same parity with the cache actually sharded over a 2-device mesh."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh(2)
    reqs = _requests(cfg, seed=11, share_prefix=8)
    _, _, res_c = _run("llm_decode", cfg, mesh, reqs, dp=2)
    wl_p, _, res_p = _run("llm_decode_paged", cfg, mesh, reqs, dp=2,
                          block_size=8)
    for r in reqs:
        np.testing.assert_array_equal(res_c[r.id].output, res_p[r.id].output)
    assert wl_p.prefix_saved_blocks > 0


def test_pallas_attn_matches_gather():
    """The paged Pallas kernel path retires the same tokens as the
    bit-exact gather path (kernel numerics differ only below argmax)."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    reqs = _requests(cfg, seed=5)
    _, _, res_g = _run("llm_decode_paged", cfg, mesh, reqs, block_size=8,
                       attn="gather")
    _, _, res_k = _run("llm_decode_paged", cfg, mesh, reqs, block_size=8,
                       attn="pallas")
    for r in reqs:
        np.testing.assert_array_equal(res_g[r.id].output, res_k[r.id].output)


# ---------------------------------------------------------------------------
# 2. Prefix sharing
# ---------------------------------------------------------------------------


def test_prefix_sharing_stores_blocks_once():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    pool = PagedDecodePool(cfg, mesh, slots=4, max_len=24, max_prompt_len=12,
                           block_size=8)
    with mesh:
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    sys_prefix = rng.integers(0, cfg.vocab, size=8)  # exactly one block
    prompts = [
        np.concatenate([sys_prefix, rng.integers(0, cfg.vocab, size=3)])
        for _ in range(4)
    ]
    for s, p in enumerate(prompts):
        pool.admit(params, p, s, max_new=6)
    # all four slots map logical block 0 to the same physical block
    shared = {pool.slot_blocks[s][0] for s in range(4)}
    assert len(shared) == 1
    bid = shared.pop()
    assert pool.allocator.ref[bid] == 4
    assert pool.prefix_saved_blocks == 3  # stored once, adopted thrice
    # later blocks are private
    assert len({pool.slot_blocks[s][1] for s in range(4)}) == 4

    # decode never writes a shared block
    snap = {
        n: np.asarray(pool.state["pages"][n][:, bid])
        for n in pool.state["pages"]
    }
    active = jnp.ones((4,), bool)
    state = pool.state
    for _ in range(5):
        state = pool.device_step(params, state, active)
    for n, before in snap.items():
        np.testing.assert_array_equal(before, np.asarray(state["pages"][n][:, bid]))

    # sharers retire independently; the block frees with the last one
    for s in range(3):
        pool.release_slot(s)
        assert pool.allocator.ref[bid] == 3 - s
    assert pool.allocator.peek(sys_prefix.astype(np.int32).tobytes()) == bid
    pool.release_slot(3)
    assert pool.allocator.ref[bid] == 0
    assert pool.allocator.peek(sys_prefix.astype(np.int32).tobytes()) is None
    assert pool.allocator.used_blocks == 0
    pool.allocator.check()


def test_prefix_sharing_served_tokens_identical():
    """Shared-prefix requests through the engine: same tokens as with
    sharing disabled, and fewer blocks touched."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    reqs = _requests(cfg, seed=9, share_prefix=8)
    wl_s, _, res_s = _run("llm_decode_paged", cfg, mesh, reqs, block_size=8,
                          share_prefixes=True)
    wl_n, _, res_n = _run("llm_decode_paged", cfg, mesh, reqs, block_size=8,
                          share_prefixes=False)
    for r in reqs:
        np.testing.assert_array_equal(res_s[r.id].output, res_n[r.id].output)
    assert wl_s.prefix_saved_blocks > 0
    assert wl_n.prefix_saved_blocks == 0


# ---------------------------------------------------------------------------
# 3. Block accounting: recycling + backpressure
# ---------------------------------------------------------------------------


def test_backpressure_waits_for_blocks():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    rng = np.random.default_rng(13)
    # 2 slots but only enough blocks for one request at a time
    wl = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=2, max_len=16,
        max_prompt_len=8, seed=0, block_size=8, num_blocks=3,
    )
    eng = ServeEngine(wl, ServeConfig())
    reqs = [
        Request(id=i, prompt=rng.integers(0, cfg.vocab, size=4), max_new=6)
        for i in range(2)
    ]
    res = eng.run(reqs)
    assert len(res) == 2  # both completed despite the block famine
    # the second could only be admitted after the first retired its blocks
    first, second = sorted(res.values(), key=lambda r: r.admit_tick)
    assert second.admit_tick >= first.retire_tick
    assert wl.pool.allocator.used_blocks == 0
    wl.pool.allocator.check()


def test_never_fitting_request_raises():
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    wl = make_workload(
        "llm_decode_paged", cfg=cfg, mesh=mesh, slots=1, max_len=16,
        max_prompt_len=8, seed=0, block_size=8, num_blocks=2,
    )
    with pytest.raises(ValueError, match="never be admitted"):
        wl.can_admit(Request(id=0, prompt=np.arange(8), max_new=20))


# ---------------------------------------------------------------------------
# 4. Capacity honesty: forced_at_capacity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", ["llm_decode", "llm_decode_paged"])
def test_forced_at_capacity_surfaced(workload):
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    wl = make_workload(
        workload, cfg=cfg, mesh=mesh, slots=1, max_len=16,
        max_prompt_len=8, seed=0,
        **({"block_size": 8} if workload == "llm_decode_paged" else {}),
    )
    # defeat the budget clamp so the request's budget exceeds the cache:
    # the slot freezes at capacity with the budget still unspent
    wl.clamp_max_new = lambda req: int(req.max_new)
    eng = ServeEngine(wl, ServeConfig())
    res = eng.run([Request(id=0, prompt=np.arange(4) + 1, max_new=500)])
    s = eng.summary()
    assert s["forced_at_capacity"] == 1
    assert not res[0].converged
    # it produced exactly the tokens the cache had room for, then stopped
    assert res[0].n_tokens < 500
    assert eng.tick < 100  # retired promptly, not after 500 ticks


def test_budget_retirement_not_counted_as_capacity(
):
    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = _mesh()
    reqs = _requests(cfg)
    _, eng, res = _run("llm_decode_paged", cfg, mesh, reqs, dp=3,
                       block_size=8)
    assert eng.summary()["forced_at_capacity"] == 0
    assert all(r.converged for r in res.values())
