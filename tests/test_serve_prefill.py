"""Single-dispatch cached prefill == per-token decode loop (serve path).

``make_cached_prefill_step`` scans the decode step over the prompt inside
one jitted program; the launcher used to dispatch a Python loop of decode
steps per prompt token.  Both must produce the same cache and the same
generations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import registry
from repro.distributed import step as step_lib
from repro.models import transformer


def _mesh():
    return compat.make_mesh(
        (1,), ("data",), devices=jax.devices()[:1],
        axis_types=compat.default_axis_types(1),
    )


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b"])
def test_cached_prefill_matches_per_token_loop(arch):
    cfg = registry.get_smoke_config(arch)
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode serving")
    mesh = _mesh()
    B, S, G = 2, 8, 4
    max_len = S + G
    serve_step, _ = step_lib.make_serve_step(cfg, mesh)
    prefill_step, _ = step_lib.make_cached_prefill_step(cfg, mesh)

    with mesh:
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (B, S), 0, cfg.vocab
        )
        jstep = jax.jit(serve_step)
        jprefill = jax.jit(prefill_step)

        # reference: the historical per-token Python loop
        cache_ref = transformer.init_cache(cfg, B, max_len)
        for i in range(S):
            logits_ref, cache_ref = jstep(
                params, prompt[:, i], cache_ref, jnp.int32(i)
            )

        # one jitted prefill dispatch
        logits_new, cache_new = jprefill(
            params, prompt, transformer.init_cache(cfg, B, max_len)
        )

    np.testing.assert_allclose(
        np.asarray(logits_new, np.float32), np.asarray(logits_ref, np.float32),
        rtol=1e-5, atol=1e-5,
    )

    # prefill+decode output is unchanged: greedy generations from both
    # caches must be token-identical
    def decode(logits, cache):
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out = []
        for i in range(G):
            out.append(np.asarray(toks))
            logits, cache = jstep(params, toks, cache, jnp.int32(S + i))
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.stack(out, 1)

    with mesh:
        ids_ref = decode(logits_ref, cache_ref)
        ids_new = decode(logits_new, cache_new)
    np.testing.assert_array_equal(ids_new, ids_ref)
