"""Chaos suite for the elastic resize runtime (DESIGN.md S12).

In-process units cover the policy registry, the keep-map algebra, protocol
state migration across p, and script legality; the slow subprocess tests
drive scripted kill/join/stall sequences across non-power-of-two extents
and assert the chaotic run's params are **bit-identical** to uninterrupted
oracle runs at each intermediate extent (stitched by ``oracle_replay``).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from chaos import ChaosScript, Join, Kill, Stall
from repro.asynchrony.protocols import (
    DETECTION_PROTOCOLS,
    RES_INIT,
    ConvergenceMonitor,
    get_protocol,
)
from repro.runtime import (
    ELASTIC_POLICIES,
    FailureDetector,
    HeartbeatConfig,
    StepClock,
    get_policy,
)
from repro.runtime.elastic import flat_keep_for_grow, flat_keep_for_shrink

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
HERE = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# Policies registry
# ---------------------------------------------------------------------------


def test_policy_registry_floor():
    assert {
        "static", "shrink_on_failure", "grow_on_join", "drain_straggler"
    } <= set(ELASTIC_POLICIES)
    with pytest.raises(ValueError, match="shrink_on_failure"):
        get_policy("scale_to_the_moon")


def _detector(workers, **hb):
    det = FailureDetector(list(workers), HeartbeatConfig(**hb))
    for w in workers:
        det.heartbeat(w, now=0.0, step_time=1.0)
    return det


def test_static_policy_aborts_on_failure():
    det = _detector([0, 1, 2, 3], timeout_s=5)
    det.mark_dead(2)
    d = get_policy("static").decide(det, 1.0, [], frozenset([0, 1, 2, 3]))
    assert d.action == "abort" and 2 in d.remove


def test_shrink_policy_ignores_joins_and_offmesh_failures():
    det = _detector([0, 1], timeout_s=5)
    pol = get_policy("shrink_on_failure")
    assert pol.decide(det, 1.0, [7], frozenset([0, 1])).action == "none"
    det.mark_dead(1)
    d = pol.decide(det, 1.0, [7], frozenset([0, 1]))
    assert d.action == "shrink" and d.remove == frozenset([1])
    # a worker that already left the mesh is not re-evicted
    assert pol.decide(det, 1.0, [], frozenset([0])).action == "none"


def test_grow_policy_prefers_shrink_then_admits():
    det = _detector([0, 1, 2], timeout_s=5)
    pol = get_policy("grow_on_join")
    d = pol.decide(det, 1.0, [5, 6], frozenset([0, 1, 2]))
    assert d.action == "grow" and set(d.admit) == {5, 6}
    det.mark_dead(0)
    assert pol.decide(det, 1.0, [5], frozenset([0, 1, 2])).action == "shrink"


def test_drain_straggler_policy_evicts_after_strikes():
    det = _detector([0, 1, 2, 3], straggler_factor=3.0,
                    evict_after_straggler_steps=2, timeout_s=1e9)
    pol = get_policy("drain_straggler")
    for t in range(1, 4):
        for w in (0, 1, 2):
            det.heartbeat(w, now=t, step_time=1.0)
        det.heartbeat(3, now=t, step_time=10.0)
        d = pol.decide(det, t, [], frozenset([0, 1, 2, 3]))
        if d.action != "none":
            break
    assert d.action == "shrink" and d.remove == frozenset([3])


# ---------------------------------------------------------------------------
# Keep-map algebra + detector/clock plumbing
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_flat_keep_shrink_and_grow_single_axis():
    mesh = _FakeMesh({"data": 4})
    assert flat_keep_for_shrink(mesh, ("data",), "data", [1, 2, 3]) == (1, 2, 3)
    assert flat_keep_for_grow(mesh, ("data",), "data", 2) == (0, 1, 2, 3, None, None)


def test_flat_keep_multi_axis_dp():
    mesh = _FakeMesh({"pod": 2, "data": 3})
    # drop data slice 1: flattened (pod-major) survivors follow their pods
    keep = flat_keep_for_shrink(mesh, ("pod", "data"), "data", [0, 2])
    assert keep == (0, 2, 3, 5)
    keep = flat_keep_for_grow(mesh, ("pod", "data"), "data", 1)
    assert keep == (0, 1, 2, None, 3, 4, 5, None)


def test_step_clock_and_detector_lifecycle():
    clk = StepClock(dt=2.0)
    assert clk.now() == 0.0 and clk.advance() == 2.0 and clk.now() == 2.0
    det = FailureDetector([0, 1], HeartbeatConfig(timeout_s=3.0), now=2.0)
    assert det.failed(now=4.0) == []  # fresh workers are not instantly dead
    det.mark_dead(0)
    assert det.failed(now=4.0) == [0]
    det.remove_worker(0)
    assert det.failed(now=4.0) == []
    det.add_worker(5, now=4.0)
    assert 5 in det.last


# ---------------------------------------------------------------------------
# Protocol state migration across p (sim states)
# ---------------------------------------------------------------------------


class _Cfg:
    max_delay = 3
    window = 0
    eps = 1e-6


@pytest.mark.parametrize("name", sorted(DETECTION_PROTOCOLS))
@pytest.mark.parametrize("keep", [(0, 2, 3), (0, 1, 2, 3, None, None)])
def test_protocol_migrate_shapes_and_latches(name, keep):
    proto = get_protocol(name)
    p_old, m = 4, 8
    st = proto.init(p_old, m, _Cfg())
    st["res_norm"] = jnp.float32(0.125)
    st["detected"] = jnp.bool_(True)
    new_p = len(keep)
    new = proto.init(new_p, m, _Cfg())  # shape reference
    migrated = proto.migrate(st, keep, new_p, m, _Cfg())
    assert jax.tree_util.tree_structure(migrated) == jax.tree_util.tree_structure(new)
    for a, b in zip(jax.tree.leaves(migrated), jax.tree.leaves(new)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # the certified value and the detection latch survive the resize
    assert float(migrated["res_norm"]) == 0.125
    assert bool(migrated["detected"])


def test_inexact_migrate_carries_worker_latches():
    proto = get_protocol("inexact")
    st = proto.init(4, 8, _Cfg())
    st["res_loc"] = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    out = proto.migrate(st, (0, 2, 3), 3, 8, _Cfg())
    np.testing.assert_array_equal(np.asarray(out["res_loc"]), [1.0, 3.0, 4.0])
    out = proto.migrate(st, (1, None, 3), 3, 8, _Cfg())
    np.testing.assert_array_equal(
        np.asarray(out["res_loc"]),
        np.asarray([2.0, RES_INIT, 4.0], np.float32),
    )
    # the in-flight staged reduction restarts from stage 0
    assert int(out["nb"]["stage"]) == 0 and not bool(out["nb"]["flag"])


def test_interval_migrate_moves_window_columns():
    proto = get_protocol("interval")
    cfg = _Cfg()
    st = proto.init(4, 8, cfg)
    W = st["win"].shape[0]
    st["win"] = jnp.broadcast_to(
        jnp.asarray([10.0, 20.0, 30.0, 40.0], jnp.float32), (W, 4)
    )
    out = proto.migrate(st, (3, 0, None), 3, 8, cfg)
    assert out["win"].shape == (W, 3)
    np.testing.assert_array_equal(
        np.asarray(out["win"][0]),
        np.asarray([40.0, 10.0, RES_INIT], np.float32),
    )
    # a joiner starts saturated: it cannot certify before filling a window
    assert float(jnp.max(out["win"][:, 2])) == float(jnp.float32(RES_INIT))


def test_exact_migrate_keeps_xbar_when_problem_size_unchanged():
    proto = get_protocol("exact")
    st = proto.init(4, 6, _Cfg())  # n = 24
    st["xbar"] = jnp.arange(24.0, dtype=jnp.float32)
    out = proto.migrate(st, (0, 1, 2), 3, 8, _Cfg())  # still n = 24
    np.testing.assert_array_equal(np.asarray(out["xbar"]), np.arange(24.0))
    assert int(out["mode"]) == 0 and not bool(out["snap"]["in_progress"])


def test_monitor_migrate_rows_selects_and_resets_nb():
    from repro.distributed.gradsync import common
    from repro.distributed.gradsync.common import TrainConfig

    mon = ConvergenceMonitor(axis_name="data", threshold=1e-3, mode="interval",
                             window=4)
    rows = common.monitor_rows_init(mon, 4)
    rows["value"] = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    rows["done"] = jnp.asarray([False, True, False, True])
    rows["m"]["win"] = jnp.broadcast_to(
        jnp.asarray([[1.0], [2.0], [3.0], [4.0]], jnp.float32), (4, 4)
    )
    rows["nb"]["stage"] = jnp.asarray([1, 1, 1, 1], jnp.int32)
    out = mon.migrate_rows(rows, (1, 3, None))
    np.testing.assert_array_equal(
        np.asarray(out["value"]),
        np.asarray([2.0, 4.0, RES_INIT], np.float32),
    )
    np.testing.assert_array_equal(np.asarray(out["done"]), [True, True, False])
    np.testing.assert_array_equal(
        np.asarray(out["m"]["win"][:, 0]),
        np.asarray([2.0, 4.0, RES_INIT], np.float32),
    )
    np.testing.assert_array_equal(np.asarray(out["nb"]["stage"]), [0, 0, 0])


# ---------------------------------------------------------------------------
# Script DSL legality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_random_scripts_are_legal(seed):
    script = ChaosScript.random(
        seed, n_steps=12, initial_devices=[0, 1, 2, 3],
        spare_devices=[4, 5], min_extent=2,
    )
    live = {0, 1, 2, 3}
    outside = {4, 5}
    for ev in script.events:
        if isinstance(ev, Kill):
            assert ev.device in live and len(live) > 2
            live.remove(ev.device)
            outside.add(ev.device)
        elif isinstance(ev, Join):
            assert set(ev.devices) <= outside
            outside -= set(ev.devices)
            live |= set(ev.devices)
    assert len(live) >= 2


def test_script_applies_each_event_once():
    class _T:
        killed = []

        def kill(self, d, silent=False):
            self.killed.append(d)

    script = ChaosScript([Kill(3, 7)])
    t = _T()
    script.apply(t, 2)
    assert t.killed == []
    script.apply(t, 3)
    script.apply(t, 3)
    assert t.killed == [7]


# ---------------------------------------------------------------------------
# Subprocess chaos runs: bit-identity vs the per-extent oracle replay
# ---------------------------------------------------------------------------

_PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {here!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.configs import registry
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig
    from repro.runtime import ElasticConfig, ElasticTrainer, HeartbeatConfig
    from chaos import (ChaosScript, Kill, Join, Stall, oracle_replay,
                       assert_params_bit_identical)

    cfg = registry.get_smoke_config("llama3.2-1b")

    def make_tcfg(**kw):
        kw.setdefault("grad_sync", "mrd_zero1")
        kw.setdefault("monitor", True)
        kw.setdefault("monitor_mode", "interval")
        kw.setdefault("monitor_threshold", 1e-6)
        return step_lib.TrainConfig(
            microbatches=1, remat="none",
            optimizer=OptimizerConfig(lr=5e-3, schedule="const", warmup_steps=0),
            **kw)

    def run_chaos(tcfg, dcfg, dev_ids, script, steps, policy, hb=None):
        mesh = compat.make_mesh(
            (len(dev_ids),), ("data",),
            devices=[jax.devices()[i] for i in dev_ids],
            axis_types=compat.default_axis_types(1))
        tr = ElasticTrainer(
            mesh, (cfg, tcfg),
            pipe_factory=lambda m: SyntheticPipeline(cfg, dcfg, m),
            checkpointer=None,
            cfg=ElasticConfig(policy=policy, heartbeat=hb or HeartbeatConfig()),
        )
        state = tr.init_or_restore(jax.random.PRNGKey(0))
        state, losses = tr.run(state, steps, events=script)
        return tr, state, losses

    def check_vs_oracle(tr, state, losses, tcfg, dcfg, dev_ids, steps, tag):
        o_state, o_losses = oracle_replay(
            cfg, tcfg, dcfg, dev_ids, tr.resizes, steps)
        assert losses == o_losses, (tag, losses, o_losses)
        assert_params_bit_identical(state["params"], o_state["params"], tag)
        assert_params_bit_identical(state["opt"], o_state["opt"], tag + ":opt")
        print(tag, "extents",
              [(e.kind, e.old_dp, e.new_dp, e.step) for e in tr.resizes],
              "OK")
    """
)


def _run(script_body: str, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", _PRELUDE.format(here=HERE) + textwrap.dedent(script_body)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout[-4000:]}\nSTDERR:\n{proc.stderr[-6000:]}"
    )
    return proc.stdout


@pytest.mark.slow
def test_chaos_kill_join_crossing_non_p2_extents():
    """The headline scenario: 4 -> 3 -> 5 -> 4, kills and joins interleaved,
    bit-identical to the chained per-extent oracle runs."""
    out = _run(
        """
        dcfg = DataConfig(batch=60, seq_len=8, seed=0)  # lcm(4,3,5) divides 60
        tcfg = make_tcfg()
        dev_ids = [0, 1, 2, 3]
        script = ChaosScript([
            Kill(2, 2),           # 4 -> 3 at step 2
            Join(4, (2, 4)),      # 3 -> 5 at step 4 (a killed worker rejoins)
            Kill(7, 1),           # 5 -> 4 at step 7
        ])
        steps = 10
        tr, state, losses = run_chaos(tcfg, dcfg, dev_ids, script, steps,
                                      "grow_on_join")
        assert [ (e.old_dp, e.new_dp) for e in tr.resizes ] == [(4,3),(3,5),(5,4)], tr.resizes
        assert [ e.step for e in tr.resizes ] == [2, 4, 7]
        assert tr.restores == 0
        assert not any(e.restored_from_checkpoint for e in tr.resizes)
        assert len(losses) == steps
        check_vs_oracle(tr, state, losses, tcfg, dcfg, dev_ids, steps, "kill-join")
        print("CHAOS-KILL-JOIN-PASSED")
        """
    )
    assert "CHAOS-KILL-JOIN-PASSED" in out


@pytest.mark.slow
def test_chaos_grow_3_to_5_without_checkpoint():
    """Acceptance: a grow 3 -> 5 resumes in place — no checkpointer exists,
    params arrive at the joiners over the MRD broadcast at p=5."""
    out = _run(
        """
        dcfg = DataConfig(batch=15, seq_len=16, seed=0)
        tcfg = make_tcfg(grad_sync="compressed")  # EF residual rides along
        dev_ids = [0, 1, 2]
        script = ChaosScript([Join(3, (3, 4))])
        steps = 7
        tr, state, losses = run_chaos(tcfg, dcfg, dev_ids, script, steps,
                                      "grow_on_join")
        assert [ (e.kind, e.old_dp, e.new_dp) for e in tr.resizes ] == [("grow", 3, 5)]
        assert tr.restores == 0 and tr.ck is None
        assert not tr.resizes[0].restored_from_checkpoint
        assert "ef" in state["opt"]
        check_vs_oracle(tr, state, losses, tcfg, dcfg, dev_ids, steps, "grow35")
        print("CHAOS-GROW-PASSED")
        """
    )
    assert "CHAOS-GROW-PASSED" in out


@pytest.mark.slow
def test_chaos_straggler_drain_and_silent_kill():
    """drain_straggler evicts a stalled worker after exactly
    evict_after_straggler_steps slow steps; a silent kill is detected
    exactly when the virtual heartbeat timeout elapses.  Both trajectories
    are bit-identical to their oracle replays."""
    out = _run(
        """
        dcfg = DataConfig(batch=12, seq_len=16, seed=0)
        tcfg = make_tcfg()
        dev_ids = [0, 1, 2, 3]

        # -- straggler drain: stall fires before step 1, two strikes evict
        hb = HeartbeatConfig(straggler_factor=3.0, evict_after_straggler_steps=2,
                             timeout_s=1e9)
        script = ChaosScript([Stall(1, 3, factor=10.0)])
        steps = 6
        tr, state, losses = run_chaos(tcfg, dcfg, dev_ids, script, steps,
                                      "drain_straggler", hb=hb)
        assert [ (e.kind, e.old_dp, e.new_dp) for e in tr.resizes ] == [("shrink", 4, 3)]
        assert "straggler" in tr.resizes[0].reason
        check_vs_oracle(tr, state, losses, tcfg, dcfg, dev_ids, steps, "drain")

        # -- silent kill: partition at step 1, timeout_s=2.5 on the injected
        #    clock -> detected before step 3 (heartbeats at now=step+1)
        hb2 = HeartbeatConfig(timeout_s=2.5)
        script2 = ChaosScript([Kill(1, 0, silent=True)])
        tr2, state2, losses2 = run_chaos(tcfg, dcfg, dev_ids, script2, steps,
                                         "shrink_on_failure", hb=hb2)
        assert [ (e.kind, e.old_dp, e.new_dp) for e in tr2.resizes ] == [("shrink", 4, 3)]
        # deterministic detection: last heartbeat at now=1, timeout 2.5,
        # heartbeats at now=step+1 -> first now - last > 2.5 is now=4 (step 3)
        assert tr2.resizes[0].step == 3, tr2.resizes
        check_vs_oracle(tr2, state2, losses2, tcfg, dcfg, dev_ids, steps, "silent")
        print("CHAOS-DRAIN-SILENT-PASSED")
        """
    )
    assert "CHAOS-DRAIN-SILENT-PASSED" in out


@pytest.mark.slow
def test_chaos_kill_join_with_overlap():
    """Elastic resize under the ready-bucket overlap path (DESIGN.md S16):
    kills and joins crossing non-power-of-two extents with ``overlap=True``
    must stay bit-identical both to the same chaotic run without overlap
    (pure-reordering invariant survives rebuilds) and to the per-extent
    oracle replay."""
    out = _run(
        """
        dcfg = DataConfig(batch=60, seq_len=8, seed=0)  # 4, 3, 5 all divide
        dev_ids = [0, 1, 2, 3]
        script = [Kill(2, 2), Join(4, (2, 4))]   # 4 -> 3 at 2, 3 -> 5 at 4
        steps = 8
        tcfg_o = make_tcfg(overlap=True)
        tr, state, losses = run_chaos(tcfg_o, dcfg, dev_ids,
                                      ChaosScript(list(script)), steps,
                                      "grow_on_join")
        assert [ (e.old_dp, e.new_dp) for e in tr.resizes ] == [(4, 3), (3, 5)]
        tcfg_b = make_tcfg(overlap=False)
        tr_b, state_b, losses_b = run_chaos(tcfg_b, dcfg, dev_ids,
                                            ChaosScript(list(script)), steps,
                                            "grow_on_join")
        assert losses == losses_b, ("overlap vs baseline", losses, losses_b)
        assert_params_bit_identical(state["params"], state_b["params"], "ovl")
        assert_params_bit_identical(state["opt"], state_b["opt"], "ovl:opt")
        check_vs_oracle(tr, state, losses, tcfg_o, dcfg, dev_ids, steps,
                        "overlap-chaos")
        print("CHAOS-OVERLAP-PASSED")
        """
    )
    assert "CHAOS-OVERLAP-PASSED" in out


@pytest.mark.slow
def test_chaos_random_seeded_scripts():
    """Seeded random legal kill/join sequences (the 'any legal sequence'
    clause): every one is bit-identical to its oracle replay."""
    out = _run(
        """
        dcfg = DataConfig(batch=60, seq_len=8, seed=0)  # extents 2..6 all divide
        tcfg = make_tcfg()
        dev_ids = [0, 1, 2, 3]
        steps = 9
        for seed in (1, 7):
            script = ChaosScript.random(
                seed, n_steps=steps, initial_devices=dev_ids,
                spare_devices=[4, 5], min_extent=2, max_events=3)
            tr, state, losses = run_chaos(tcfg, dcfg, dev_ids, script, steps,
                                          "grow_on_join")
            assert tr.restores == 0
            check_vs_oracle(tr, state, losses, tcfg, dcfg, dev_ids, steps,
                            f"rand{seed}")
        print("CHAOS-RANDOM-PASSED")
        """
    )
    assert "CHAOS-RANDOM-PASSED" in out
