"""Differential suite for the ready-bucket grad-sync overlap (DESIGN.md S16).

The overlap path MUST be a pure reordering: the same BucketLayout, the same
per-bucket stage math, only the *issue order* changes.  Three layers of
bit-exactness checks:

1. engine:   BucketPipeline admit/advance/drain == CollectivePlan.run_buffers
             for every schedule family, p in {2,3,5,8}, staggered admission;
2. gradient: segmented (3-VJP) backward == the monolithic value_and_grad
             backward, across model families and microbatch counts;
3. end-to-end (slow, 8 host devices): a full jitted train step with
             ``overlap=True`` == ``overlap=False`` — per-step losses and the
             entire final state tree bitwise, for all four converted
             grad-sync modes, non-power-of-two DP extents, a bf16 param
             variant, and the compressed mode's EF residual carry.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.collectives import plans  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticPipeline  # noqa: E402
from repro.distributed.gradsync import common, overlap as overlap_lib  # noqa: E402


# ---------------------------------------------------------------------------
# 1. BucketPipeline == run_buffers (sim executor, bitwise)
# ---------------------------------------------------------------------------

def _sim_bufs(plan, p, n_buckets=4, seed=0):
    q = plan.pad_quantum()
    rng = np.random.default_rng(seed)
    bufs = []
    for i in range(n_buckets):
        n = q * (i + 2)
        bufs.append(jnp.asarray(
            rng.standard_normal((p, n)).astype(np.float32)))
    return bufs


def _pipeline_staggered(plan, bufs):
    """Admit bucket k only after k advance() rounds — the worst-case
    interleaving the overlap path can produce (every bucket at a different
    stage depth while later ones are still being admitted)."""
    pipe = plan.pipeline()
    out = {}
    for k, b in enumerate(bufs):
        pipe.admit(k, b)
        pipe.advance()
    out.update(pipe.drain())
    return [out[k] for k in range(len(bufs))]


_PLAN_FAMILIES = {
    "mrd_ar": lambda p: plans.allreduce_plan(
        schedule="mrd", p=p, op="sum", executor="sim"),
    "rabenseifner_ar": lambda p: plans.allreduce_plan(
        schedule="rabenseifner", p=p, op="sum", executor="sim"),
    "mrd_ar_int8": lambda p: plans.allreduce_plan(
        schedule="mrd", p=p, op="sum", transform="int8", executor="sim"),
    "primitive_rs": lambda p: plans.reduce_scatter_plan(
        p=p, op="sum", executor="sim"),
}


@pytest.mark.parametrize("family", sorted(_PLAN_FAMILIES))
@pytest.mark.parametrize("p", [2, 3, 5, 8])
def test_pipeline_matches_run_buffers(family, p):
    plan = _PLAN_FAMILIES[family](p)
    bufs = _sim_bufs(plan, p, seed=p)
    want = plan.run_buffers([b for b in bufs])
    got = _pipeline_staggered(plan, bufs)
    assert len(got) == len(want)
    for k, (g, w) in enumerate(zip(got, want)):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"{family} p={p} bucket {k}: staggered pipeline diverges from "
            f"run_buffers")


def test_pipeline_all_admitted_up_front_matches():
    """Admitting everything before the first advance() (the no-overlap
    admission order driven through the same engine) is also bitwise equal."""
    plan = _PLAN_FAMILIES["mrd_ar"](5)
    bufs = _sim_bufs(plan, 5, seed=42)
    want = plan.run_buffers([b for b in bufs])
    pipe = plan.pipeline()
    for k, b in enumerate(bufs):
        pipe.admit(k, b)
    out = pipe.drain()
    for k, w in enumerate(want):
        assert np.array_equal(np.asarray(out[k]), np.asarray(w))


def test_pipeline_duplicate_admit_rejected():
    plan = _PLAN_FAMILIES["mrd_ar"](3)
    bufs = _sim_bufs(plan, 3, n_buckets=2)
    pipe = plan.pipeline()
    pipe.admit(0, bufs[0])
    with pytest.raises(ValueError):
        pipe.admit(0, bufs[1])


# ---------------------------------------------------------------------------
# 2. segmented_grads == microbatched_grads (single device, bitwise)
# ---------------------------------------------------------------------------

def _collect_segmented(params, batch, cfg, mb):
    gen = overlap_lib.segmented_grads(params, batch, cfg, None, mb)
    loss, metrics = next(gen)
    merged = {}
    names = []
    for name, piece in gen:
        names.append(name)
        merged.update(piece)
    assert names == list(overlap_lib.GROUP_NAMES)
    grads = {k: merged[k] for k in params}
    return loss, metrics, grads


_SEG_ARCHS = ["llama3.2-1b", "gemma3-12b", "mixtral-8x7b", "falcon-mamba-7b"]


@pytest.mark.parametrize("arch", _SEG_ARCHS)
def test_segmented_grads_bitwise(arch):
    cfg = registry.get_smoke_config(arch)
    params = jax.jit(lambda k: __import__(
        "repro.models.transformer", fromlist=["transformer"]
    ).init_params(cfg, k))(jax.random.PRNGKey(0))
    batch = SyntheticPipeline(cfg, DataConfig(batch=4, seq_len=16, seed=0)).next_batch()

    ref_grads, ref_loss, _ = jax.jit(
        lambda p, b: common.microbatched_grads(p, b, cfg, None, 1)
    )(params, batch)
    loss, _, grads = jax.jit(
        lambda p, b: _collect_segmented(p, b, cfg, 1)
    )(params, batch)

    assert np.asarray(loss) == np.asarray(ref_loss)
    mism = []
    jax.tree_util.tree_map_with_path(
        lambda path, a, b: mism.append(jax.tree_util.keystr(path))
        if not np.array_equal(np.asarray(a), np.asarray(b)) else None,
        grads, ref_grads,
    )
    assert not mism, f"{arch}: segmented grads differ bitwise at {mism[:5]}"


def test_segmented_grads_bitwise_microbatched():
    """mb=2: the first microbatch runs through the identical segmented
    path under scan — grads must stay bitwise (the scalar mean loss may
    re-associate inside XLA fusion, so it only gets allclose)."""
    cfg = registry.get_smoke_config("llama3.2-1b")
    from repro.models import transformer

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = SyntheticPipeline(cfg, DataConfig(batch=4, seq_len=16, seed=0)).next_batch()

    ref_grads, ref_loss, _ = jax.jit(
        lambda p, b: common.microbatched_grads(p, b, cfg, None, 2)
    )(params, batch)
    loss, _, grads = jax.jit(
        lambda p, b: _collect_segmented(p, b, cfg, 2)
    )(params, batch)

    np.testing.assert_allclose(
        np.asarray(loss), np.asarray(ref_loss), rtol=1e-6)
    ok = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        grads, ref_grads)
    assert all(jax.tree.leaves(ok)), "mb=2 segmented grads differ bitwise"


def test_group_partition_covers_params():
    """Every top-level param key lands in exactly one readiness group and
    the per-leaf group labels agree with the key offsets."""
    cfg = registry.get_smoke_config("gemma3-12b")
    from repro.models import transformer

    pshape = jax.eval_shape(
        lambda k: transformer.init_params(cfg, k), jax.random.PRNGKey(0))
    head, stack, embed = overlap_lib._split_params(pshape)
    assert set(head) | set(stack) | set(embed) == set(pshape.keys())
    assert not (set(head) & set(stack)) and not (set(stack) & set(embed))
    lgroups = overlap_lib.leaf_groups(pshape)
    offs = overlap_lib.key_offsets(pshape)
    for k in pshape:
        g = overlap_lib.group_of_key(k)
        n = len(jax.tree.leaves(pshape[k]))
        assert lgroups[offs[k]: offs[k] + n] == [g] * n


# ---------------------------------------------------------------------------
# 3. End-to-end: jitted train step, overlap on == off (8 devices, slow)
# ---------------------------------------------------------------------------

_SCRIPT = textwrap.dedent(
    """
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = flags + " --xla_force_host_platform_device_count=8"

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.configs import registry
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig

    def run(mode, dp, overlap, cfg, steps=3):
        mesh = compat.make_mesh(
            (dp,), ("data",),
            axis_types=compat.default_axis_types(1),
            devices=jax.devices()[:dp],
        )
        tcfg = step_lib.TrainConfig(
            microbatches=1, remat="none", grad_sync=mode,
            monitor=False, bucket_bytes=1 << 15, overlap=overlap,
            optimizer=OptimizerConfig(lr=1e-3, schedule="const", warmup_steps=0),
        )
        train_step, init_state, state_specs, rules = step_lib.make_train_step(
            cfg, mesh, tcfg)
        with mesh:
            state = init_state(jax.random.PRNGKey(0))
            from jax.sharding import NamedSharding
            shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), state_specs(state))
            state = jax.device_put(state, shardings)
            pipe = SyntheticPipeline(
                cfg, DataConfig(batch=8, seq_len=16, seed=1), mesh)
            jstep = jax.jit(train_step)
            losses = []
            for _ in range(steps):
                state, metrics = jstep(state, pipe.next_batch())
                losses.append(np.asarray(metrics["loss"]))
        flat = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
            flat[jax.tree_util.keystr(path)] = np.asarray(jax.device_get(leaf))
        return losses, flat

    def compare(mode, dp, cfg, tag=""):
        l0, s0 = run(mode, dp, False, cfg)
        l1, s1 = run(mode, dp, True, cfg)
        for i, (a, b) in enumerate(zip(l0, l1)):
            assert np.array_equal(a, b), (
                f"{mode}{tag} dp={dp} step {i}: loss {a!r} != {b!r}")
        assert set(s0) == set(s1)
        for k in s0:
            assert np.array_equal(s0[k], s1[k]), (
                f"{mode}{tag} dp={dp}: state leaf {k} differs bitwise")
        print(f"OK {mode}{tag} dp={dp} ({len(s0)} leaves bitwise)")
        return s1

    cfg = registry.get_smoke_config("llama3.2-1b")

    # the ZeRO-1 MRD mode across every DP-extent class (p2, odd, prime)
    for dp in (2, 3, 5, 8):
        compare("mrd_zero1", dp, cfg)

    # the other converted modes: one non-power-of-two + one power-of-two
    for mode in ("mrd_paper", "mrd_leaf"):
        for dp in (3, 8):
            compare(mode, dp, cfg)

    # compressed: EF residual must carry identically through the overlap path
    for dp in (3, 8):
        s = compare("compressed", dp, cfg)
        ef = [v for k, v in s.items() if "'ef'" in k]
        assert ef, "compressed state has no EF residual leaf"
        assert any(np.any(v != 0) for v in ef), (
            "EF residual never populated — carry lost")

    # bf16 params: the dtype-split bucket layout under overlap
    cfg_bf16 = registry.override(cfg, param_dtype="bfloat16")
    compare("mrd_zero1", 5, cfg_bf16, tag="-bf16")

    print("ALL-OVERLAP-DIFF-PASSED")
    """
)


@pytest.mark.slow
def test_overlap_vs_baseline_train_step():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout[-4000:]}\nSTDERR:\n{proc.stderr[-6000:]}"
    )
    assert "ALL-OVERLAP-DIFF-PASSED" in proc.stdout
