"""MoE routing: grouped-scatter dispatch vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe


def _setup(E=4, k=2, d=32, f=64, B=2, S=16, cf=8.0, seed=0):
    cfg = registry.override(
        registry.get_smoke_config("mixtral-8x7b"),
        n_experts=E, top_k=k, d_model=d, d_ff=f, capacity_factor=cf,
    )
    key = jax.random.PRNGKey(seed)
    p = moe.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d), jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("k", [1, 2, 3])
def test_scatter_matches_dense_with_ample_capacity(k):
    """With capacity high enough that nothing drops, scatter == dense oracle."""
    cfg, p, x = _setup(k=k, cf=16.0)
    y_s, aux_s = moe.moe_apply(p, x, cfg, impl="scatter")
    y_d, aux_d = moe.moe_apply(p, x, cfg, impl="dense")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_capacity_drops_reduce_output_norm():
    """Tight capacity drops tokens (outputs zeroed), never corrupts others."""
    cfg, p, x = _setup(cf=0.25)
    y_tight, _ = moe.moe_apply(p, x, cfg, impl="scatter")
    y_full, _ = moe.moe_apply(p, x, cfg, impl="dense")
    # some tokens zeroed -> smaller norm, but no NaN/garbage
    assert np.all(np.isfinite(np.asarray(y_tight)))
    assert np.linalg.norm(y_tight) <= np.linalg.norm(y_full) * 1.5


def test_group_locality():
    """Dispatch is per-group: permuting one group's tokens never changes
    another group's outputs (the property that makes it DP-shardable)."""
    cfg, p, x = _setup(B=3, cf=1.0)
    y0, _ = moe.moe_apply(p, x, cfg, impl="scatter")
    x_perm = x.at[0].set(x[0, ::-1])  # permute group 0's tokens
    y1, _ = moe.moe_apply(p, x_perm, cfg, impl="scatter")
    np.testing.assert_allclose(
        np.asarray(y0[1:]), np.asarray(y1[1:]), rtol=1e-6, atol=1e-6
    )


def test_grad_flows_through_scatter():
    cfg, p, x = _setup()
    g = jax.grad(lambda q: moe.moe_apply(q, x, cfg, impl="scatter")[0].sum())(p)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    assert float(jnp.abs(g["w1"]).sum()) > 0
