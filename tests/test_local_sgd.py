"""Bounded-staleness local SGD: replicas diverge between syncs, converge at
sync points (the async-iterations idea applied to training)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro import compat

    from repro.configs import registry
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.distributed import step as step_lib
    from repro.optim.optimizer import OptimizerConfig

    cfg = registry.get_smoke_config("llama3.2-1b")
    mesh = compat.make_mesh((4,), ("data",), axis_types=compat.default_axis_types(1))
    tcfg = step_lib.TrainConfig(
        microbatches=1, remat="none", grad_sync="local_sgd", monitor=False,
        local_sync_every=4,
        optimizer=OptimizerConfig(lr=5e-3, schedule="const", warmup_steps=0))
    train_step, init_state, state_specs, _ = step_lib.make_train_step(cfg, mesh, tcfg)
    with mesh:
        state = init_state(jax.random.PRNGKey(0))
        state = jax.device_put(state, jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_specs(state)))
        pipe = SyntheticPipeline(cfg, DataConfig(batch=8, seq_len=32, seed=0), mesh)
        js = jax.jit(train_step)
        losses = []
        for i in range(16):
            state, m = js(state, pipe.next_batch())
            losses.append(float(m["loss"]))
            # replica divergence across DP shards
            w = np.asarray(state["params"]["embed"], np.float32)  # [4, V, d]
            spread = np.abs(w - w[0]).max()
            synced = (i + 1) % 4 == 0
            if synced:
                assert spread < 1e-5, f"step {i}: replicas differ after sync ({spread})"
            print(f"step {i}: loss={losses[-1]:.3f} replica_spread={spread:.2e} synced={synced}")
        assert np.mean(losses[-4:]) < np.mean(losses[:4]) + 0.02, losses
    print("LOCAL-SGD-PASSED")
    """
)


@pytest.mark.slow
def test_local_sgd_bounded_staleness():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-5000:]}"
    assert "LOCAL-SGD-PASSED" in proc.stdout
