"""Property tests for the paged-cache block allocator (DESIGN.md S14).

Model-based hypothesis tests drive :class:`repro.serving.paged.BlockAllocator`
through arbitrary alloc/release/share/fork sequences against a reference
refcount model, checking the load-bearing invariants:

- a block is never handed out twice while allocated (no double-assignment);
- a block returns to the free list exactly when its last sharer releases it;
- copy-on-write (``fork_private``) never touches a block other sharers
  still hold — the writer moves to a fresh block instead;
- the prefix registry only ever points at live blocks and is dropped with
  the last reference.

Plus example-based tests for the host-side block planner
(``PagedDecodePool._plan_blocks``): cumulative-prefix sharing, write-mask
shape, and clean rollback on exhaustion.
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.serving.paged import BlockAllocator, PagedDecodePool


# ---------------------------------------------------------------------------
# Example-based allocator behavior
# ---------------------------------------------------------------------------


def test_alloc_is_deterministic_lowest_first():
    a = BlockAllocator(6, 8)
    assert [a.alloc() for _ in range(5)] == [1, 2, 3, 4, 5]
    with pytest.raises(MemoryError):
        a.alloc()
    a.release(3)
    assert a.alloc() == 3  # immediate reuse of the freed block
    a.check()


def test_trash_block_is_pinned():
    a = BlockAllocator(4, 8)
    with pytest.raises(ValueError):
        a.release(0)
    with pytest.raises(ValueError):
        a.retain(0)
    with pytest.raises(ValueError):
        a.register(b"k", 0)
    a.check()


def test_registry_lifecycle():
    a = BlockAllocator(4, 8)
    b = a.alloc()
    a.register(b"sys", b)
    assert a.peek(b"sys") == b
    assert a.lookup(b"sys") == b  # second sharer
    assert a.ref[b] == 2
    assert not a.release(b)  # first sharer leaves: still live
    assert a.peek(b"sys") == b
    assert a.release(b)  # last sharer leaves: freed + deregistered
    assert a.peek(b"sys") is None
    assert a.free_blocks == 3
    a.check()


def test_fork_private_cow():
    a = BlockAllocator(5, 8)
    b = a.alloc()
    a.register(b"sys", b)
    a.lookup(b"sys")  # second sharer
    nb, copied = a.fork_private(b)
    assert copied and nb != b  # shared: writer moved to a fresh block
    assert a.ref[b] == 1 and a.peek(b"sys") == b  # sharer's view untouched
    nb2, copied2 = a.fork_private(nb)
    assert nb2 == nb and not copied2  # exclusive: write in place
    a.check()


def test_fork_private_oom_keeps_reference():
    a = BlockAllocator(2, 8)  # single usable block
    b = a.alloc()
    a.retain(b)  # shared, and no free block to fork into
    with pytest.raises(MemoryError):
        a.fork_private(b)
    assert a.ref[b] == 2  # the failed fork must not leak the caller's ref
    a.check()


# ---------------------------------------------------------------------------
# Model-based: arbitrary op sequences vs a reference refcount model
# ---------------------------------------------------------------------------


OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 1 << 30), st.integers(0, 7)),
    max_size=80,
)


@given(OPS, st.integers(2, 12))
@settings(max_examples=200, deadline=None)
def test_allocator_model(ops, num_blocks):
    a = BlockAllocator(num_blocks, 8)
    held = []  # every reference this "client" holds, one entry per ref
    for code, x, y in ops:
        if code == 0:  # alloc
            if a.free_blocks:
                b = a.alloc()
                assert b != 0 and b not in held  # no double-assignment
                held.append(b)
            else:
                with pytest.raises(MemoryError):
                    a.alloc()
        elif code == 1 and held:  # release one reference
            b = held.pop(x % len(held))
            freed = a.release(b)
            assert freed == (b not in held)  # freed iff last sharer left
        elif code == 2 and held:  # retain (extra sharer)
            b = held[x % len(held)]
            a.retain(b)
            held.append(b)
        elif code == 3 and held:  # register/lookup through the registry
            b = held[x % len(held)]
            key = bytes([y])
            owner = a.peek(key)
            if owner is None:
                a.register(key, b)
                owner = b
            got = a.lookup(key)
            assert got == owner
            held.append(got)
        elif code == 4 and held:  # fork_private (COW)
            b = held[x % len(held)]
            if held.count(b) == 1:
                nb, copied = a.fork_private(b)
                assert nb == b and not copied
            elif a.free_blocks:
                others = held.count(b) - 1
                held.remove(b)
                nb, copied = a.fork_private(b)
                assert copied and nb != b and nb not in held
                assert a.ref[b] == others  # sharers keep the old block
                held.append(nb)
            else:
                with pytest.raises(MemoryError):
                    a.fork_private(b)
        # cross-check the reference model and the structural invariants
        counts = np.bincount(held, minlength=num_blocks) if held else (
            np.zeros(num_blocks, np.int64)
        )
        assert (a.ref[1:] == counts[1:]).all()
        a.check()
    for b in list(held):  # drain: everything must come back
        held.remove(b)
        a.release(b)
    assert a.free_blocks == num_blocks - 1
    a.check()


# ---------------------------------------------------------------------------
# Host-side block planning (no device state needed)
# ---------------------------------------------------------------------------


def _planner(num_blocks, *, block_size=4, max_len=16, share=True):
    """A PagedDecodePool stripped to its host-side planning half."""
    p = object.__new__(PagedDecodePool)
    p.block_size = block_size
    p.max_len = max_len
    p.max_prompt_len = max_len - block_size
    p.share_prefixes = share
    p.blocks_per_slot = max_len // block_size
    p.num_blocks = num_blocks
    p.allocator = BlockAllocator(num_blocks, block_size)
    return p


def test_plan_shares_cumulative_prefix_blocks():
    p = _planner(32)
    sys_prefix = np.arange(8, dtype=np.int32)  # 2 full blocks
    pa = np.concatenate([sys_prefix, [101, 102]]).astype(np.int32)
    pb = np.concatenate([sys_prefix, [201]]).astype(np.int32)
    ba, wa, sa = p._plan_blocks(pa, len(pa), 2)
    bb, wb, sb = p._plan_blocks(pb, len(pb), 2)
    assert sa == 0 and sb == 2  # second request adopts both prefix blocks
    assert bb[:2] == ba[:2] and bb[2] not in ba
    assert wa == [True] * 4 and wb == [False, False, True]
    assert (p.allocator.ref[ba[:2]] == 2).all()
    # divergent prefix shares nothing
    pc = np.concatenate([[9] * 8, [301]]).astype(np.int32)
    bc, wc, sc = p._plan_blocks(pc, len(pc), 2)
    assert sc == 0 and not set(bc) & set(ba)
    p.allocator.check()


def test_plan_partial_block_prefix_not_shared():
    p = _planner(32)
    pa = np.arange(6, dtype=np.int32)  # 1 full block + 2 tokens
    ba, wa, _ = p._plan_blocks(pa, len(pa), 4)
    bb, wb, sb = p._plan_blocks(pa.copy(), len(pa), 4)
    assert sb == 1  # only the full block is shared
    assert bb[0] == ba[0] and bb[1] != ba[1]  # the half-written one is private
    assert wb == [False, True, True]
    p.allocator.check()


def test_plan_rolls_back_on_exhaustion():
    p = _planner(3)  # 2 usable blocks
    big = np.arange(8, dtype=np.int32)
    free0 = p.allocator.free_blocks
    with pytest.raises(MemoryError):
        p._plan_blocks(big, len(big), 8)  # needs 3 blocks, only 2 exist
    assert p.allocator.free_blocks == free0  # clean rollback
    p.allocator.check()


def test_can_admit_rejects_never_fitting_request():
    p = _planner(3, block_size=4, max_len=16)
    with pytest.raises(ValueError):
        p.can_admit(np.arange(8, dtype=np.int32), 16)  # needs 4 > 2 usable
    assert p.can_admit(np.arange(4, dtype=np.int32), 2)  # 2 blocks: fits
    p.allocator.alloc()
    # still fits in principle (2 <= 2 usable) but not right now (1 free)
    assert not p.can_admit(np.arange(4, dtype=np.int32), 2)


@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),  # prompt family (shared alphabet -> collisions)
            st.integers(1, 11),  # prompt length
            st.integers(1, 6),  # max_new
        ),
        min_size=1,
        max_size=24,
    )
)
@settings(max_examples=100, deadline=None)
def test_plan_release_cycles_conserve_blocks(reqs):
    p = _planner(64, block_size=4, max_len=16)
    plans = []
    for fam, plen, max_new in reqs:
        prompt = np.full((plen,), fam, np.int32)
        try:
            blocks, mask, _ = p._plan_blocks(prompt, plen, max_new)
        except MemoryError:
            continue
        assert len(blocks) == len(mask) <= p.blocks_per_slot
        # every writable block is exclusively owned
        for b, w in zip(blocks, mask):
            if w:
                assert p.allocator.ref[b] == 1
        plans.append(blocks)
        p.allocator.check()
    for blocks in plans:
        for b in blocks:
            p.allocator.release(b)
    assert p.allocator.free_blocks == 63
    p.allocator.check()
