"""flash_scan (tiled online-softmax) vs full attention equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models.attention import attention


def _qkv(key, B, Sq, Skv, H, KV, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("HKV", [(4, 4), (8, 2)])
def test_flash_equals_full(causal, window, HKV):
    H, KV = HKV
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 48, 48, H, KV, 16)
    full = attention(q, k, v, causal=causal, window=window, impl="full")
    flash = attention(q, k, v, causal=causal, window=window, impl="flash_scan", chunk=16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=2e-5, atol=2e-5)


@given(
    sq=st.integers(1, 40),
    skv=st.integers(8, 70),
    chunk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_flash_equals_full_property(sq, skv, chunk, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, sq, skv, 4, 2, 8)
    # decode-style: q positions continue after the kv prefix when sq < skv
    off = max(skv - sq, 0)
    full = attention(q, k, v, causal=True, q_offset=off, impl="full")
    flash = attention(q, k, v, causal=True, q_offset=off, impl="flash_scan", chunk=chunk)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=3e-5, atol=3e-5)


def test_flash_valid_len_masking():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 1, 64, 4, 4, 8)
    full = attention(q, k, v, causal=False, impl="full", k_valid_len=37)
    flash = attention(q, k, v, causal=False, impl="flash_scan", chunk=16, k_valid_len=37)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_flash_grads_match_full():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 24, 24, 4, 2, 8)

    def loss(impl):
        return lambda q_: jnp.sum(
            attention(q_, k, v, causal=True, impl=impl, chunk=8) ** 2
        )

    gf = jax.grad(loss("full"))(q)
    gs = jax.grad(loss("flash_scan"))(q)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gf), rtol=1e-4, atol=1e-4)
