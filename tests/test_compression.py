"""int8 blockwise compression: error bounds + error-feedback property."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.collectives import compression as C


@given(
    nblocks=st.integers(1, 16),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 10000),
)
@settings(max_examples=40, deadline=None)
def test_quantization_error_bound(nblocks, scale, seed):
    """|x - deq(q(x))| <= amax_block/254 elementwise (half-ulp of the grid)."""
    n = nblocks * C.BLOCK
    x = jnp.asarray(
        np.random.default_rng(seed).standard_normal(n) * scale, jnp.float32
    )
    q, s = C.quantize(x)
    err = np.abs(np.asarray(C.dequantize(q, s) - x))
    amax = np.abs(np.asarray(x)).reshape(nblocks, C.BLOCK).max(1)
    bound = np.repeat(amax / 254.0, C.BLOCK) + 1e-7
    assert np.all(err <= bound * 1.01)


def test_quantize_preserves_zeros_and_signs():
    x = jnp.asarray([0.0] * 128 + [1.0] * 64 + [-1.0] * 64, jnp.float32)
    q, s = C.quantize(x)
    deq = np.asarray(C.dequantize(q, s))
    assert np.all(deq[:128] == 0.0)
    assert np.all(deq[128:192] > 0)
    assert np.all(deq[192:] < 0)


def test_error_feedback_converges():
    """EF-SGD property: with error feedback, the *accumulated* transmitted
    signal tracks the accumulated true signal (bias-free compression)."""
    rng = np.random.default_rng(0)
    n = 512
    true_sum = np.zeros(n)
    sent_sum = np.zeros(n)
    ef = jnp.zeros(n, jnp.float32)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
        with_ef = g + ef
        q, s = C.quantize(with_ef)
        sent = C.dequantize(q, s)
        ef = with_ef - sent
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
    # residual error is bounded by one step's quantization error, not O(T)
    resid = np.abs(true_sum - sent_sum)
    assert resid.max() < 0.01, resid.max()


def test_wire_bytes_factor():
    assert abs(C.wire_bytes_factor(4) - (1 + 4 / 256) / 4) < 1e-9
    assert C.wire_bytes_factor(2) < 0.51
